"""Crash-safe on-disk snapshots of the eager index (BM25S §3.3 save/load).

The availability half of the residency story: ``bm25s`` ships
``save``/``load(mmap=True)`` as a headline feature — a process restart
must cost a file read, not a full tokenize+build. This module gives
:class:`~.block_csr.DeviceIndex` the same property with the rigor PR 6
brought to in-request faults: typed errors, exact recovery, deterministic
injection.

On-disk format (version 2; version-1 stores still load)
--------------------------------------------------------

A snapshot is a DIRECTORY; each save writes a fresh *generation* and
commits it with one atomic pointer flip::

    <path>/
      CURRENT                 # tiny JSON: {"generation": "gen-000001"}
      gen-000001/
        manifest.json         # + manifest.json.dup replica
        index.indptr.bin      # [V+1] <i8   (+ .dup.bin replica)
        index.nonoccurrence.bin  # [V] <f4  (+ .dup.bin)
        index.doc_lens.bin    # [n_docs] <i4 (+ .dup.bin)
        csc.doc_ids.bin       # [1, nnz_pad] <i4 — upload-ready padded CSC
        csc.scores.bin        # [1, nnz_pad] <f4
        perm.bin              # [n_docs] <i4 (+ .dup.bin) — v2, reordered
        blocked.tok.bin       # [nb, p_pad] <i4   (optional section)
        blocked.loc.bin       # [nb, p_pad] <i4
        blocked.sc.bin        # [nb, p_pad] <f4
        bmax.host.bin         # [V, nb_pad] <f4 or |u1 (optional section)
        bmax.scale.bin        # [V] <f4

Every array file is raw little-endian C-order bytes — exactly what
``np.memmap`` maps — and the CSC/blocked files store the PADDED layouts
``DeviceIndex.build`` would have produced, so a cold start uploads them
straight from the memmap through ``put_posting_arrays`` with no host-side
re-blocking (the unpadded ``BM25Index`` views are slices of the same
maps). The manifest records dtype/shape/byte-count and a per-array
checksum (xxh3_64 when ``xxhash`` is importable, crc32 otherwise — the
algorithm is recorded, never guessed) plus a checksum over its own
canonical JSON.

Doc-id reordering (version 2): an index built with
``DeviceIndex.build(reorder=...)`` (``sparse.reorder``) serves its
layouts in a PERMUTED doc-id space. On disk the ``index.*`` and
``csc.*`` sections always stay in CLIENT order — the order ``load_index``
hands back and the corpus rebuild rung reproduces — while ``blocked.*``
and ``bmax.*`` stay in the layout (permuted) order they are uploaded in.
The permutation itself is the ``perm`` array (``new_id -> old_id``, with
a ``.dup`` replica), and the manifest's device section records the
``reorder`` mode. Reordered device loads therefore pay one host-side
lexsort to re-permute the CSC before upload; unordered snapshots (the
default) keep the straight-from-memmap upload path. Version-1 stores
have no ``perm`` entry and load exactly as before.

Atomic write path
-----------------

``save`` writes everything into a temp sibling dir (``.tmp-gen-*``),
fsyncs every file and the dir, renames it to its generation name, fsyncs
the parent, and only then commits with a single ``os.replace`` of the
``CURRENT`` pointer (written via its own temp + fsync). A crash at ANY
point leaves ``CURRENT`` naming the previous intact generation — a
mid-save kill can never corrupt the last committed snapshot. Old
generations and crash debris are garbage-collected after the flip.

Recovery ladder (exact at every hop)
------------------------------------

Verification failures walk, in order, and record every hop:

1. **duplicate copy** — the manifest and the small ``index.*`` arrays
   carry byte-identical ``.dup`` replicas; a single corrupted copy falls
   back to its replica.
2. **rebuild from the surviving layout** — CSC and blocked store the same
   postings, so either rebuilds the other bit-exactly (``indptr`` comes
   back from blocked token counts, ``nonoccurrence`` is recomputed from
   df + params with ``build_index``'s exact f64→f32 formula, the
   block-max table rebuilds from the CSC arrays; a corrupt ``perm`` is
   recomputed from the client-order postings — the signature pass is a
   deterministic function of the index — and accepted only when its bytes
   reproduce the manifest checksum, else the load falls back to IDENTITY
   order and rebuilds the permuted layouts from the client-order CSC:
   exact either way, the fallback merely forfeits the reorder speedup).
3. **full rebuild from a provided ``corpus=``** — when both posting
   copies are gone.
4. **typed raise** — :class:`~..serve.errors.SnapshotIntegrityError`
   (listing the corrupt entries) or
   :class:`~..serve.errors.SnapshotVersionError` (unknown format /
   version / checksum algo; a well-formed manifest with a future version
   is authoritative — no dup retry, never reinterpreted).

Hops land in the returned index's ``snapshot_report`` (surfaced by
``DeviceRetriever.health()``) and the module-level :data:`COUNTERS`.

Fault-injection lane (``repro.serve.faults``)
---------------------------------------------

``snapshot.write`` (torn write: a file is truncated on disk and the save
raises before the commit point), ``snapshot.manifest``
(``manifest_corrupt`` / ``stale_version``) and ``snapshot.array``
(``truncate`` / ``bit_flip``) mutate the REAL files this module is about
to verify — pure functions of ``(seed, fire_count)`` — so tests and the
CI chaos job probe the whole save→crash→load→recover cycle end to end.
The sites use the standard zero-cost ``sys.modules`` peek.
"""

from __future__ import annotations

import contextlib
import json
import os
import shutil
import sys
import zlib
from dataclasses import dataclass, replace

import numpy as np

from ..serve.errors import SnapshotIntegrityError, SnapshotVersionError
from .block_csr import (
    BlockMaxTable,
    DeviceIndex,
    _round_up,
    block_postings_from_index,
    build_block_max,
    put_descriptor_array,
    put_posting_arrays,
)

FORMAT = "repro-bm25s-snapshot"
VERSION = 2
_CHUNK = 1 << 22            # checksum/read granularity (4 MiB)
_DUP_ARRAYS = ("index.indptr", "index.nonoccurrence", "index.doc_lens",
               "perm")

# load/save observability (mirrors faults.FIRED's role for the I/O lane)
COUNTERS = {
    "saves": 0,
    "loads": 0,
    "dup_recoveries": 0,       # manifest or array served from its replica
    "section_rebuilds": 0,     # layout rebuilt from the surviving layout
    "full_rebuilds": 0,        # rebuilt from a provided corpus
    "integrity_failures": 0,   # typed SnapshotIntegrityError raises
    "version_failures": 0,     # typed SnapshotVersionError raises
}


def reset_counters() -> dict:
    for k in COUNTERS:
        COUNTERS[k] = 0
    return COUNTERS


# -- checksums ----------------------------------------------------------------

class _Crc32:
    """hashlib-shaped zlib.crc32 accumulator (stdlib fallback algo)."""

    def __init__(self):
        self._v = 0

    def update(self, data) -> None:
        self._v = zlib.crc32(data, self._v)

    def hexdigest(self) -> str:
        return f"{self._v & 0xFFFFFFFF:08x}"


def default_algo() -> str:
    try:
        import xxhash  # noqa: F401
        return "xxh3_64"
    except ImportError:
        return "crc32"


def _new_hasher(algo: str):
    if algo == "xxh3_64":
        try:
            import xxhash
        except ImportError as e:
            COUNTERS["version_failures"] += 1
            raise SnapshotVersionError(
                "snapshot uses xxh3_64 checksums but xxhash is not "
                "importable in this environment") from e
        return xxhash.xxh3_64()
    if algo == "crc32":
        return _Crc32()
    COUNTERS["version_failures"] += 1
    raise SnapshotVersionError(f"unknown checksum algorithm {algo!r}")


def checksum_bytes(data, algo: str) -> str:
    h = _new_hasher(algo)
    mv = memoryview(data).cast("B")
    for off in range(0, len(mv), _CHUNK):
        h.update(mv[off:off + _CHUNK])
    return h.hexdigest()


def checksum_file(path: str, algo: str) -> str:
    h = _new_hasher(algo)
    with open(path, "rb") as fh:
        while True:
            chunk = fh.read(_CHUNK)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()


def manifest_checksum(manifest: dict) -> str:
    """Checksum over the manifest's canonical JSON (sans the field itself).

    Canonical form (sorted keys, compact separators) — a whitespace-only
    file mutation that still parses to the same content is harmless by
    construction, a content mutation always mismatches.
    """
    body = {k: v for k, v in manifest.items() if k != "manifest_checksum"}
    payload = json.dumps(body, sort_keys=True,
                         separators=(",", ":")).encode("utf-8")
    return checksum_bytes(payload, manifest["algo"])


# -- atomic write path --------------------------------------------------------

def _as_le(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a)
    if a.dtype.byteorder == ">":
        a = a.astype(a.dtype.newbyteorder("<"))
    return a


def _write_file(dirpath: str, name: str, data) -> str:
    p = os.path.join(dirpath, name)
    with open(p, "wb") as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
    return p


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _gc(path: str, *, keep: str | None) -> None:
    """Best-effort removal of crash debris and superseded generations."""
    for entry in os.listdir(path):
        full = os.path.join(path, entry)
        stale_tmp = entry.startswith(".tmp-") or entry == "CURRENT.tmp"
        old_gen = (entry.startswith("gen-") and entry != keep
                   and keep is not None)
        if stale_tmp or old_gen:
            with contextlib.suppress(OSError):
                if os.path.isdir(full):
                    shutil.rmtree(full, ignore_errors=True)
                else:
                    os.unlink(full)


def _next_generation(path: str) -> str:
    gens = [int(e[4:]) for e in os.listdir(path)
            if e.startswith("gen-") and e[4:].isdigit()]
    return f"gen-{(max(gens) + 1 if gens else 1):06d}"


def _write_generation(path: str, arrays: dict, body: dict, algo: str) -> dict:
    """Write one generation and atomically commit the CURRENT pointer.

    ``arrays`` maps manifest names to numpy arrays (names listed in
    ``_DUP_ARRAYS`` get a byte-identical ``.dup.bin`` replica). Returns
    the committed manifest. Fault site ``snapshot.write`` fires once with
    the list of files just written, BEFORE the commit point — an armed
    torn-write fault truncates one of them and raises, which is exactly
    what a mid-save kill leaves behind: debris, and the previous
    generation still committed.
    """
    os.makedirs(path, exist_ok=True)
    _gc(path, keep=None)                       # debris from earlier crashes
    gen = _next_generation(path)
    tmp = os.path.join(path, f".tmp-{gen}.{os.getpid()}")
    os.makedirs(tmp)
    specs: dict[str, dict] = {}
    written: list[str] = []
    for name, arr in arrays.items():
        arr = _as_le(np.asarray(arr))
        data = arr.tobytes()
        fname = f"{name}.bin"
        written.append(_write_file(tmp, fname, data))
        spec = {"file": fname, "dtype": arr.dtype.str,
                "shape": list(arr.shape), "nbytes": len(data),
                "checksum": checksum_bytes(data, algo)}
        if name in _DUP_ARRAYS:
            spec["dup"] = f"{name}.dup.bin"
            written.append(_write_file(tmp, spec["dup"], data))
        specs[name] = spec
    manifest = {"format": FORMAT, "version": VERSION, "algo": algo,
                **body, "arrays": specs}
    manifest["manifest_checksum"] = manifest_checksum(manifest)
    mdata = json.dumps(manifest, indent=1, sort_keys=True).encode("utf-8")
    written.append(_write_file(tmp, "manifest.json", mdata))
    _write_file(tmp, "manifest.json.dup", mdata)
    _fsync_dir(tmp)
    _f = sys.modules.get("repro.serve.faults")
    if _f is not None and _f.ACTIVE:
        _f.fire("snapshot.write", written)
    os.rename(tmp, os.path.join(path, gen))
    _fsync_dir(path)
    cur = json.dumps({"generation": gen}).encode("utf-8")
    _write_file(path, "CURRENT.tmp", cur)
    os.replace(os.path.join(path, "CURRENT.tmp"),
               os.path.join(path, "CURRENT"))          # the commit point
    _fsync_dir(path)
    _gc(path, keep=gen)
    COUNTERS["saves"] += 1
    return manifest


def _padded_csc(index, frag: int) -> tuple[np.ndarray, np.ndarray]:
    """Host CSC arrays in DeviceIndex.build's padded [1, nnz_pad] layout."""
    nnz = int(index.doc_ids.size)
    nnz_pad = _round_up(max(nnz, 1), frag) + frag
    doc = np.zeros((1, nnz_pad), np.int32)
    sc = np.zeros((1, nnz_pad), np.float32)
    doc[0, :nnz] = index.doc_ids
    sc[0, :nnz] = index.scores
    return doc, sc


def _manifest_body(index, *, block_size: int, tile_p: int, frag: int,
                   nnz: int, nnz_pad: int, with_blocked: bool,
                   bmax_meta: dict | None, reorder: str = "none") -> dict:
    # exactness proof computed at SAVE time: the nonoccurrence<-recompute
    # recovery hop replays build_index's formula from the LOCAL df/n_docs,
    # which diverges for shards built with global stats — the hop is
    # offered only when the replay reproduces the stored vector bit-for-bit
    # (always true for single-shard builds and for sparse variants, whose
    # vector is identically zero)
    recomputable = bool(np.array_equal(
        _recompute_nonoccurrence(np.asarray(index.indptr),
                                 int(index.n_docs), index.params),
        np.asarray(index.nonoccurrence)))
    return {
        "index": {
            "n_docs": int(index.n_docs), "n_vocab": int(index.n_vocab),
            "l_avg": float(index.l_avg), "variant": str(index.variant),
            "doc_offset": int(index.doc_offset),
            "nonocc_recomputable": recomputable,
            "params": {"k1": index.params.k1, "b": index.params.b,
                       "delta": index.params.delta,
                       "method": index.params.method},
        },
        "device": {
            "block_size": int(block_size), "tile_p": int(tile_p),
            "frag": int(frag), "nnz": int(nnz), "nnz_pad": int(nnz_pad),
            "with_blocked": bool(with_blocked), "bmax": bmax_meta,
            "reorder": str(reorder),
        },
    }


def save_device_index(di: DeviceIndex, path: str, *, index=None,
                      algo: str | None = None) -> dict:
    """Snapshot a DeviceIndex's layouts (host copies preferred, device
    copies downloaded when the host side was dropped). For a DeviceIndex
    built with ``reorder=``, the passed ``index`` is the PERMUTED serving
    copy (``di.host``): the ``index.*``/``csc.*`` sections are unpermuted
    back to CLIENT order on the way out, ``blocked.*``/``bmax.*`` keep
    the layout order they serve in, and the ``perm`` array (+ ``.dup``)
    joins the store. Returns the committed manifest."""
    index = index if index is not None else di.host
    if index is None:
        raise ValueError(
            "save_device_index needs host metadata; the DeviceIndex was "
            "built with host_arrays='drop' — pass the retriever's stripped "
            "index via index=")
    algo = algo or default_algo()
    perm = getattr(di, "perm", None)
    reorder = getattr(di, "reorder", "none") if perm is not None else "none"
    nnz = int(index.indptr[-1])
    host_intact = int(index.doc_ids.size) == nnz
    # one full posting copy in the LAYOUT (permuted) order
    if host_intact:
        index_l = index
    elif di.csc_doc_ids is not None:
        index_l = replace(index,
                          doc_ids=np.asarray(di.csc_doc_ids)[0, :nnz],
                          scores=np.asarray(di.csc_scores)[0, :nnz])
    else:
        raise ValueError("no intact posting copy to snapshot (host arrays "
                         "stripped and no resident CSC layout)")
    if perm is not None:
        # disk keeps index.*/csc.* in CLIENT order — load_index returns
        # client ids untouched, the corpus rebuild rung reproduces the
        # files bit-exactly, and a lost perm stays recomputable
        from .reorder import unpermute_index
        index_c = unpermute_index(index_l, perm)
    else:
        index_c = index_l
    if di.csc_doc_ids is not None and perm is None:
        doc_pad = np.asarray(di.csc_doc_ids)
        sc_pad = np.asarray(di.csc_scores)
    else:
        doc_pad, sc_pad = _padded_csc(index_c, di.frag)
    if di.blk_tok is not None:
        blk = (np.asarray(di.blk_tok), np.asarray(di.blk_loc),
               np.asarray(di.blk_sc))
    elif host_intact:
        bp = block_postings_from_index(index_l, block_size=di.block_size,
                                       tile=di.tile_p)
        blk = (bp.token_ids, bp.local_doc, bp.scores)
    else:
        blk = None
    bmax_meta = None
    arrays = {
        "index.indptr": index_c.indptr,
        "index.nonoccurrence": index_c.nonoccurrence,
        "index.doc_lens": index_c.doc_lens,
        "csc.doc_ids": doc_pad,
        "csc.scores": sc_pad,
    }
    if perm is not None:
        arrays["perm"] = np.asarray(perm).astype(np.int32)
    if blk is not None:
        arrays["blocked.tok"], arrays["blocked.loc"], arrays["blocked.sc"] \
            = blk
    if di.bmax is not None:
        bm = di.bmax
        bmax_meta = {"quantized": bool(bm.quantized),
                     "n_blocks": int(bm.n_blocks), "nb_pad": int(bm.nb_pad),
                     "over_budget": bool(bm.over_budget)}
        arrays["bmax.host"] = bm.host
        arrays["bmax.scale"] = bm.scale
    body = _manifest_body(index_c, block_size=di.block_size,
                          tile_p=di.tile_p, frag=di.frag, nnz=nnz,
                          nnz_pad=int(doc_pad.shape[1]),
                          with_blocked=blk is not None, bmax_meta=bmax_meta,
                          reorder=reorder)
    return _write_generation(path, arrays, body, algo)


def save_index(index, path: str, *, block_size: int = 512, tile: int = 512,
               frag: int = 512, with_blocked: bool = True,
               algo: str | None = None) -> dict:
    """Snapshot a bare BM25Index (no device involvement — scipy shards)."""
    algo = algo or default_algo()
    doc_pad, sc_pad = _padded_csc(index, frag)
    arrays = {
        "index.indptr": index.indptr,
        "index.nonoccurrence": index.nonoccurrence,
        "index.doc_lens": index.doc_lens,
        "csc.doc_ids": doc_pad,
        "csc.scores": sc_pad,
    }
    tile_p = tile
    if with_blocked:
        bp = block_postings_from_index(index, block_size=block_size,
                                       tile=tile)
        tile_p = min(tile, bp.nnz_pad)
        arrays["blocked.tok"] = bp.token_ids
        arrays["blocked.loc"] = bp.local_doc
        arrays["blocked.sc"] = bp.scores
    body = _manifest_body(index, block_size=block_size, tile_p=tile_p,
                          frag=frag, nnz=int(index.doc_ids.size),
                          nnz_pad=int(doc_pad.shape[1]),
                          with_blocked=with_blocked, bmax_meta=None)
    return _write_generation(path, arrays, body, algo)


# -- verified read + recovery ladder ------------------------------------------

def _parse_manifest(mpath: str) -> dict:
    with open(mpath, encoding="utf-8") as fh:
        m = json.load(fh)
    fmt = m.get("format") if isinstance(m, dict) else None
    if fmt != FORMAT:
        COUNTERS["version_failures"] += 1
        raise SnapshotVersionError(
            f"{mpath}: not a {FORMAT} manifest (format={fmt!r})")
    v = m.get("version")
    if not isinstance(v, int) or not 1 <= v <= VERSION:
        COUNTERS["version_failures"] += 1
        raise SnapshotVersionError(
            f"{mpath}: snapshot version {v!r} not supported "
            f"(this build reads versions 1..{VERSION})")
    if manifest_checksum(m) != m.get("manifest_checksum"):
        raise SnapshotIntegrityError(f"{mpath}: manifest checksum mismatch",
                                     corrupt=["manifest"])
    return m


def _read_manifest(gen_dir: str, hops: list[str]) -> dict:
    mpath = os.path.join(gen_dir, "manifest.json")
    _f = sys.modules.get("repro.serve.faults")
    if _f is not None and _f.ACTIVE:
        _f.fire("snapshot.manifest", mpath)
    try:
        return _parse_manifest(mpath)
    except SnapshotVersionError:
        raise                       # authoritative — a replica can't help
    except (SnapshotIntegrityError, OSError, ValueError) as primary_err:
        try:
            m = _parse_manifest(mpath + ".dup")
        except SnapshotVersionError:
            raise
        except (SnapshotIntegrityError, OSError, ValueError):
            COUNTERS["integrity_failures"] += 1
            raise SnapshotIntegrityError(
                f"{mpath}: manifest and replica both unreadable "
                f"({primary_err})", corrupt=["manifest"]) from primary_err
        hops.append("manifest<-dup")
        COUNTERS["dup_recoveries"] += 1
        return m


def _file_ok(path: str, spec: dict, algo: str, verify: bool) -> bool:
    try:
        if os.path.getsize(path) != int(spec["nbytes"]):
            return False
        if verify and int(spec["nbytes"]) > 0:
            return checksum_file(path, algo) == spec["checksum"]
        return True
    except OSError:
        return False


def _load_array(path: str, spec: dict, mmap: bool) -> np.ndarray:
    shape = tuple(spec["shape"])
    dtype = np.dtype(spec["dtype"])
    if int(spec["nbytes"]) == 0:
        return np.zeros(shape, dtype)        # np.memmap rejects empty files
    if mmap:
        return np.memmap(path, dtype=dtype, mode="r", shape=shape)
    with open(path, "rb") as fh:
        return np.fromfile(fh, dtype=dtype).reshape(shape)


def _indptr_from_blocked(blk_tok: np.ndarray, n_vocab: int) -> np.ndarray:
    t = blk_tok[blk_tok >= 0].astype(np.int64)
    indptr = np.zeros(n_vocab + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(np.bincount(t, minlength=n_vocab))
    return indptr


def _csc_from_blocked(blk_tok, blk_loc, blk_sc, *, block_size: int,
                      nnz: int, nnz_pad: int, perm=None):
    """Bit-exact CSC posting arrays back out of the blocked layout.

    Blocked holds the same (token, doc, score) triples; a stable lexsort
    by (token, doc) restores the CSC invariant exactly, so the recovered
    stream is byte-identical to what was lost. For a reordered snapshot
    the blocked layout lives in the PERMUTED id space while the CSC
    section is stored in client order — ``perm`` maps each recovered doc
    id back before the sort, keeping the recovery bit-exact. Returns
    padded ``[1, nnz_pad]`` arrays, or None when the posting counts
    disagree (an internally inconsistent donor — fall through to corpus
    rebuild).
    """
    mask = blk_tok >= 0
    t = blk_tok[mask].astype(np.int64)
    if int(t.size) != nnz:
        return None
    blk_of = np.broadcast_to(
        np.arange(blk_tok.shape[0], dtype=np.int64)[:, None], blk_tok.shape)
    d = (blk_of * block_size + blk_loc)[mask]
    if perm is not None:
        d = np.asarray(perm).astype(np.int64)[d]
    s = blk_sc[mask]
    order = np.lexsort((d, t))
    doc_pad = np.zeros((1, nnz_pad), np.int32)
    sc_pad = np.zeros((1, nnz_pad), np.float32)
    doc_pad[0, :nnz] = d[order]
    sc_pad[0, :nnz] = s[order]
    return doc_pad, sc_pad


def _recompute_nonoccurrence(indptr: np.ndarray, n_docs: int,
                             params) -> np.ndarray:
    """Replay build_index's exact nonoccurrence formula (f64 → f32)."""
    from ..core.variants import get_variant
    variant = get_variant(params.method)
    df = np.diff(indptr).astype(np.float64)
    nonocc = np.where(
        df > 0, variant.nonoccurrence(np.maximum(df, 1.0), n_docs, params),
        0.0)
    return nonocc.astype(np.float32)


@dataclass
class _Loaded:
    """Everything _read_snapshot recovered, ready to wrap or upload."""

    index: object                   # BM25Index (memmap-backed when mmap)
    csc_doc: np.ndarray | None      # [1, nnz_pad] (None after full rebuild)
    csc_sc: np.ndarray | None
    blk: tuple | None               # (tok, loc, sc) or None
    bmax_host: np.ndarray | None
    bmax_scale: np.ndarray | None
    bmax_meta: dict | None
    bmax_rebuild: bool              # bmax section corrupt — rebuild on load
    manifest: dict
    report: dict
    full_rebuild: bool
    perm: np.ndarray | None = None  # new_id -> old_id (index stays CLIENT
    #                                 order; device loads re-permute)
    reorder: str = "none"           # manifest's recorded reorder mode


def _read_snapshot(path: str, *, mmap: bool, verify: bool,
                   corpus) -> _Loaded:
    from ..core.index import BM25Index, build_index
    from ..core.variants import BM25Params

    hops: list[str] = []
    _f = sys.modules.get("repro.serve.faults")
    scope = _f.guard() if _f is not None else contextlib.nullcontext()

    with scope:     # guarded I/O faults fire only where recovery exists
        cur_path = os.path.join(path, "CURRENT")
        try:
            with open(cur_path, encoding="utf-8") as fh:
                gen = json.load(fh)["generation"]
            gen_dir = os.path.join(path, gen)
            if not os.path.isdir(gen_dir):
                raise OSError(f"generation dir {gen_dir} missing")
        except (OSError, ValueError, KeyError) as e:
            COUNTERS["integrity_failures"] += 1
            raise SnapshotIntegrityError(
                f"no committed snapshot at {path!r} ({e})",
                corrupt=["CURRENT"]) from e
        manifest = _read_manifest(gen_dir, hops)
        algo = manifest["algo"]
        _new_hasher(algo)           # unknown algo → typed version error
        arrays: dict[str, dict] = manifest["arrays"]
        primaries = [os.path.join(gen_dir, s["file"])
                     for s in arrays.values()]
        if _f is not None and _f.ACTIVE:
            _f.fire("snapshot.array", primaries)
        # verify every file; small arrays fall back to their replicas
        usable: dict[str, str] = {}
        bad: set[str] = set()
        for name, spec in arrays.items():
            p = os.path.join(gen_dir, spec["file"])
            if _file_ok(p, spec, algo, verify):
                usable[name] = p
            elif spec.get("dup") and _file_ok(
                    os.path.join(gen_dir, spec["dup"]), spec, algo, verify):
                usable[name] = os.path.join(gen_dir, spec["dup"])
                hops.append(f"{name}<-dup")
                COUNTERS["dup_recoveries"] += 1
            else:
                bad.add(name)

    mi = manifest["index"]
    dev = manifest["device"]
    params = BM25Params(**mi["params"])
    n_vocab = int(mi["n_vocab"])
    n_docs = int(mi["n_docs"])
    nnz, nnz_pad = int(dev["nnz"]), int(dev["nnz_pad"])
    block_size = int(dev["block_size"])

    def arr(name: str) -> np.ndarray:
        return _load_array(usable[name], arrays[name], mmap)

    blocked_present = "blocked.tok" in arrays
    blocked_names = {"blocked.tok", "blocked.loc", "blocked.sc"}
    blocked_ok = blocked_present and not (bad & blocked_names)
    csc_ok = not (bad & {"csc.doc_ids", "csc.scores"})
    recovered: dict[str, str] = {}
    full = False

    # -- perm, stage 1 (v2 reordered stores): file-level resolution.
    # blocked.*/bmax.* live in the PERMUTED doc space, index.*/csc.* in
    # client order — cross-layout recovery below needs the map between
    # them, so resolve the perm file (primary, then its .dup, both already
    # folded into usable/bad) before any posting rung runs.
    from .reorder import is_permutation, signature_permutation
    perm_present = "perm" in arrays
    perm_arr = None
    perm_file_ok = False
    if perm_present and "perm" not in bad:
        cand = np.asarray(arr("perm"))
        if is_permutation(cand, n_docs):
            perm_arr, perm_file_ok = cand.astype(np.int32), True
        else:
            bad.add("perm")     # invalid bytes slipped past verify=False

    blk = None
    if blocked_ok:
        blk = (arr("blocked.tok"), arr("blocked.loc"), arr("blocked.sc"))

    if "index.indptr" in bad:
        if blocked_ok:
            indptr = _indptr_from_blocked(blk[0], n_vocab)
            recovered["index.indptr"] = "blocked"
        else:
            full = True
    else:
        indptr = arr("index.indptr")

    csc_doc = csc_sc = None
    if csc_ok:
        csc_doc, csc_sc = arr("csc.doc_ids"), arr("csc.scores")
    elif blocked_ok and not full and (perm_file_ok or not perm_present):
        # a reordered snapshot's blocked layout holds PERMUTED doc ids —
        # without a trustworthy perm the client-order CSC can't come back
        # from it (and the perm recompute rung needs the CSC), so that
        # double corruption falls through to the corpus rung
        rebuilt = _csc_from_blocked(*blk, block_size=block_size, nnz=nnz,
                                    nnz_pad=nnz_pad, perm=perm_arr)
        if rebuilt is None:
            full = True
        else:
            csc_doc, csc_sc = rebuilt
            recovered["csc"] = "blocked"
    else:
        full = True

    if "index.nonoccurrence" in bad:
        # the replay is exact only when the save-time proof says so (a
        # shard built with GLOBAL stats stores a vector the local-df
        # replay cannot reproduce — fall through to the corpus rung)
        if not full and mi.get("nonocc_recomputable", False):
            nonocc = _recompute_nonoccurrence(indptr, n_docs, params)
            recovered["index.nonoccurrence"] = "recomputed"
        else:
            full = True
    else:
        nonocc = arr("index.nonoccurrence")

    if "index.doc_lens" in bad:
        full = True                 # replica failed too — not derivable
    else:
        doc_lens = arr("index.doc_lens")

    if full:
        if corpus is None:
            COUNTERS["integrity_failures"] += 1
            raise SnapshotIntegrityError(
                f"snapshot at {path!r} has unrecoverable corruption "
                f"({sorted(bad)}) and no corpus= was provided for a full "
                f"rebuild", corrupt=sorted(bad))
        # ``corpus`` is the FULL tokenized corpus the index came from:
        # stats are global (shards score with global df/N/L_avg) and the
        # shard's own documents are the manifest-recorded slice — exact
        # for single-shard and sharded builds alike
        from ..core.index import CorpusStats
        off = int(mi["doc_offset"])
        stats = CorpusStats.from_corpus(corpus, n_vocab)
        index = build_index(corpus[off:off + n_docs], n_vocab,
                            params=params, stats=stats, doc_offset=off)
        recovered["full"] = "corpus"
        COUNTERS["full_rebuilds"] += 1
        COUNTERS["loads"] += 1
        report = {"path": path, "generation": gen, "mmap": bool(mmap),
                  "verified": bool(verify), "algo": algo,
                  "corrupt": sorted(bad), "recovered": recovered,
                  "hops": hops + ["full<-corpus"], "full_rebuild": True}
        return _Loaded(index=index, csc_doc=None, csc_sc=None, blk=None,
                       bmax_host=None, bmax_scale=None,
                       bmax_meta=dev.get("bmax"), bmax_rebuild=False,
                       manifest=manifest, report=report, full_rebuild=True,
                       perm=None, reorder=str(dev.get("reorder", "none")))

    index = BM25Index(
        indptr=indptr, doc_ids=csc_doc[0, :nnz], scores=csc_sc[0, :nnz],
        nonoccurrence=nonocc, doc_lens=doc_lens, n_docs=n_docs,
        n_vocab=n_vocab, l_avg=float(mi["l_avg"]),
        variant=str(mi["variant"]), params=params,
        doc_offset=int(mi["doc_offset"]))

    # -- perm, stage 2: both copies corrupt — recompute the signature
    # pass from the recovered client-order postings (a deterministic
    # function of the index) and accept it ONLY when its bytes reproduce
    # the manifest checksum. Otherwise serve in IDENTITY order: the
    # on-disk permuted blocked/bmax layouts index an unmappable doc space,
    # so they are dropped and rebuilt from the client-order CSC below —
    # exact either way, identity merely forfeits the reorder speedup.
    perm = perm_arr
    perm_dropped = False
    if perm_present and not perm_file_ok:
        mode = str(dev.get("reorder", "none"))
        cand = (signature_permutation(index, mode=mode)
                if mode != "none" else None)
        if cand is not None and checksum_bytes(
                _as_le(cand.astype(np.int32)).tobytes(),
                algo) == arrays["perm"]["checksum"]:
            perm = cand
            recovered["perm"] = "signatures"
        else:
            perm = None
            perm_dropped = True
            recovered["perm"] = "identity"

    if blocked_present and (not blocked_ok or perm_dropped):
        from .reorder import permute_index
        src = permute_index(index, perm) if perm is not None else index
        bp = block_postings_from_index(src, block_size=block_size,
                                       tile=int(dev["tile_p"]))
        blk = (bp.token_ids, bp.local_doc, bp.scores)
        recovered["blocked"] = "csc"

    bmax_meta = dev.get("bmax")
    bmax_host = bmax_scale = None
    bmax_rebuild = False
    if bmax_meta is not None:
        if not (bad & {"bmax.host", "bmax.scale"}) and not perm_dropped:
            bmax_host, bmax_scale = arr("bmax.host"), arr("bmax.scale")
        else:
            bmax_rebuild = True     # device loads rebuild from the index
            recovered["bmax"] = "csc"

    section_hops = [f"{k}<-{v}" for k, v in recovered.items()]
    COUNTERS["section_rebuilds"] += len(recovered)
    COUNTERS["loads"] += 1
    report = {"path": path, "generation": gen, "mmap": bool(mmap),
              "verified": bool(verify), "algo": algo,
              "corrupt": sorted(bad), "recovered": recovered,
              "hops": hops + section_hops, "full_rebuild": False}
    return _Loaded(index=index, csc_doc=csc_doc, csc_sc=csc_sc, blk=blk,
                   bmax_host=bmax_host, bmax_scale=bmax_scale,
                   bmax_meta=bmax_meta, bmax_rebuild=bmax_rebuild,
                   manifest=manifest, report=report, full_rebuild=False,
                   perm=perm,
                   reorder=(str(dev.get("reorder", "none"))
                            if perm is not None else "none"))


def _strip_host(index):
    """Posting-free metadata copy (host_arrays='drop'): releases the
    posting memmaps while keeping what planners and packers read."""
    return replace(
        index, indptr=np.array(index.indptr),
        nonoccurrence=np.array(index.nonoccurrence),
        doc_lens=np.array(index.doc_lens),
        doc_ids=np.zeros(0, np.int32), scores=np.zeros(0, np.float32))


def load_index(path: str, *, mmap: bool = False, verify: bool = True,
               corpus=None):
    """Verified host-only load — a BM25Index, no device uploads.

    The returned index's arrays are read-only ``np.memmap`` views when
    ``mmap=True``; ``index.snapshot_report`` records the verification and
    any recovery hops. ``corpus`` arms the last recovery rung and must be
    the FULL tokenized corpus the index was built from — the loader
    derives global stats from it and rebuilds only the manifest-recorded
    document slice, so sharded indexes recover exactly too.
    """
    ld = _read_snapshot(path, mmap=mmap, verify=verify, corpus=corpus)
    ld.index.snapshot_report = ld.report
    return ld.index


def load_device_index(path: str, *, mmap: bool = False,
                      host_arrays: str = "keep", verify: bool = True,
                      corpus=None) -> DeviceIndex:
    """Cold-start a DeviceIndex from a snapshot — no host re-blocking.

    The padded CSC and blocked files upload straight through
    ``put_posting_arrays`` (from the memmap when ``mmap=True``), so the
    TRANSFERS counters see exactly one posting upload per layout and the
    zero-steady-state-bytes invariant holds for every batch after.
    ``host_arrays="drop"`` keeps only the posting-free metadata copy as
    ``di.host`` (unlike ``DeviceIndex.build``, which sets it to None —
    loads hand the stripped copy over so adopting retrievers need no
    separate index argument).
    """
    if host_arrays not in ("keep", "drop"):
        raise ValueError(f"unknown host_arrays mode {host_arrays!r}")
    ld = _read_snapshot(path, mmap=mmap, verify=verify, corpus=corpus)
    dev = ld.manifest["device"]
    if ld.full_rebuild:
        meta = ld.bmax_meta
        di = DeviceIndex.build(
            ld.index, block_size=int(dev["block_size"]),
            tile=int(dev["tile_p"]), frag=int(dev["frag"]),
            with_blocked=bool(dev["with_blocked"]), with_csc=True,
            with_bmax=meta is not None,
            bmax_dtype=("u8" if meta and meta["quantized"] else "f32")
            if meta else "auto",
            # the signature pass is deterministic — the rebuilt
            # DeviceIndex recomputes the exact permutation the snapshot
            # was serving with
            reorder=ld.reorder)
    else:
        index = ld.index
        if ld.perm is not None:
            # disk stores index.*/csc.* in CLIENT order; the resident
            # layouts serve in the PERMUTED space — re-permute the host
            # copy (one lexsort) and pad its CSC for upload
            from .reorder import permute_index
            index = permute_index(index, ld.perm)
        di = DeviceIndex(
            host=index, indptr=index.indptr, df=np.diff(index.indptr),
            nnz=int(dev["nnz"]), n_docs=int(index.doc_lens.size),
            n_vocab=int(index.n_vocab),
            doc_offset=int(index.doc_offset),
            block_size=int(dev["block_size"]), tile_p=int(dev["tile_p"]),
            frag=int(dev["frag"]),
            reused={"csc": False, "blocked": False, "bmax": False},
            perm=ld.perm, reorder=ld.reorder)
        if ld.perm is not None:
            doc_pad, sc_pad = _padded_csc(index, di.frag)
        else:
            doc_pad, sc_pad = ld.csc_doc, ld.csc_sc
        di.csc_doc_ids, di.csc_scores = put_posting_arrays(doc_pad, sc_pad)
        di.csc_indptr = put_descriptor_array(
            np.asarray(index.indptr).astype(np.int32))
        if ld.blk is not None:
            di.blk_tok, di.blk_loc, di.blk_sc = put_posting_arrays(*ld.blk)
            di.tile_p = min(int(dev["tile_p"]), int(ld.blk[0].shape[1]))
        if ld.bmax_rebuild:
            di.bmax = build_block_max(
                index, block_size=di.block_size,
                dtype="u8" if ld.bmax_meta["quantized"] else "f32")
        elif ld.bmax_host is not None:
            meta = ld.bmax_meta
            bm = BlockMaxTable(
                host=np.asarray(ld.bmax_host),
                scale=np.asarray(ld.bmax_scale),
                quantized=bool(meta["quantized"]),
                block_size=di.block_size, n_blocks=int(meta["n_blocks"]),
                nb_pad=int(meta["nb_pad"]),
                over_budget=bool(meta["over_budget"]))
            bm.device = put_descriptor_array(bm.host)
            bm.scale_dev = put_descriptor_array(bm.scale)
            di.bmax = bm
    if host_arrays == "drop":
        # strip the SERVING-order host copy (permuted when reordered):
        # retrievers and re-saves need doc_lens in the layouts' id space
        di.host = _strip_host(di.host if di.host is not None else ld.index)
        di.indptr = di.host.indptr
        di.df = np.diff(di.indptr)
    di.snapshot_report = ld.report
    return di


__all__ = [
    "FORMAT", "VERSION", "COUNTERS", "reset_counters", "default_algo",
    "checksum_bytes", "checksum_file", "manifest_checksum",
    "save_device_index", "save_index", "load_index", "load_device_index",
]
