"""Top-k selection and the sharded retrieval step.

The paper's §2 "Top-k selection": average-O(n) partition-based selection
(np.argpartition) or JAX/XLA ``top_k`` — it observes the JAX path is faster
in practice, so that is our device default.

At pod scale the corpus is document-sharded; top-k generalizes losslessly to
a two-stage merge: per-shard local top-k (each shard's winners are a superset
of its contribution to the global winners), all-gather the ``k`` candidates
per shard (tiny: ``shards × k × 8B``), then a global top-k over
``shards × k``. ``sharded_retrieve`` expresses this with ``shard_map`` so the
same code runs on 1 device (tests) and 512 chips (dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .scoring import DeviceIndex, score_query


# -- retrieval planner (cost model over the two device regimes) --------------
#
# The full-scan regime streams EVERY posting tile: O(nnz) per batch, perfect
# locality, zero descriptor work. The gathered regime touches only the
# batch's posting runs: O(Σ df) plus per-run overhead (descriptor build,
# fragment padding, candidate bookkeeping). Both costs are known BEFORE any
# kernel runs — Σ df comes from the host descriptor table (O(U) adds), nnz
# is index metadata — so the regime choice is a free host-side comparison of
#
#     work_ratio = nnz / Σ df(batch uniq tokens)   vs   CROSSOVER
#
# CROSSOVER folds the gathered path's per-posting overhead factor into one
# constant: at work_ratio == CROSSOVER the two regimes break even, above it
# the gather's asymptotic advantage dominates. The default below is
# calibrated from the BENCH_3 sweep (benchmarks/planner.py), which measures
# both forced regimes across corpus-size × df-profile cells and reports the
# implied break-even band; re-calibrate on TPU by re-running
# ``python -m benchmarks.planner`` there and copying the suggested value.

DEFAULT_CROSSOVER = 2.0

# With DEVICE-side fragment planning (``sparse.fragment_device``) the
# gathered regime no longer pays the per-batch O(Σ df) host descriptor walk
# or the descriptor upload — the fixed overhead CROSSOVER folds in shrinks,
# so the break-even moves TOWARD the gather. The discount below scales the
# default crossover when the caller plans on device; like the crossover
# itself it is a calibration constant — re-measure on TPU with
# ``python -m benchmarks.planner`` after kernel/schedule changes.
DEVICE_PLAN_DISCOUNT = 0.75

# The PRUNED regime executes the gathered machinery over only the fragments
# whose block-max upper bound can still beat the top-k threshold — its
# modeled cost is the gathered cost scaled by the estimated surviving-work
# fraction, DIVIDED by this discount: the survivor estimate is discounted
# for the fixed overhead pruning adds (the bound matmul, the seed pass that
# certifies the threshold, and the re-scored seed blocks), so pruning must
# be expected to cut at least (1 - PRUNE_DISCOUNT) of the gathered work
# before the planner will pick it. Calibrate from the BENCH_4 pruned cells
# (``python -m benchmarks.planner`` — re-run ON TPU; the suggested
# procedure is in ROADMAP's three-regime section).
PRUNE_DISCOUNT = 0.5


@dataclass
class RetrievalPlan:
    """One batch's regime decision plus the evidence it was made on.

    The ``frags_*`` counters are filled in by the executing retriever
    (zero until then): ``frags_planned`` is the batch's full fragment
    count, ``frags_pruned`` how many the pre-launch threshold compaction
    removed, ``frags_skipped`` how many more the in-kernel scoreboard test
    skipped mid-launch.

    ``degradations`` is the batch's fallback trail: one entry per ladder
    hop the executing retriever was forced to take (empty on the healthy
    path), each a dict ``{"from", "to", "error", "detail"}`` — see the
    ROADMAP "Fault tolerance" section for the hop order.
    """

    regime: str             # "blocked" | "gathered" | "pruned"
    sum_df: int             # Σ df over the batch's unique tokens
    nnz: int                # the shard's posting count (full-scan work)
    work_ratio: float       # nnz / max(sum_df, 1)
    crossover: float        # threshold used
    forced: bool            # True when the operator pinned the regime
    plan: str = "host"      # where the fragment table is built
    survivor_frac: float | None = None  # pruning-work estimate fed to auto
    frags_planned: int = 0
    frags_pruned: int = 0
    frags_skipped: int = 0
    degradations: list = field(default_factory=list)


def plan_retrieval(sum_df: int, nnz: int, *, regime: str = "auto",
                   crossover: float | None = None,
                   plan: str = "host",
                   survivor_frac: float | None = None) -> RetrievalPlan:
    """Pick full-scan vs gathered vs pruned for one batch (free — no
    device work).

    ``regime="blocked"``/``"gathered"``/``"pruned"`` force that regime
    (the plan still records the evidence, so forced decisions stay
    debuggable); ``"auto"`` compares modeled per-batch costs:

    * blocked   — ``nnz`` (stream every posting tile);
    * gathered  — ``crossover × Σ df`` (the crossover folds the gather's
      per-posting overhead into one constant, so the old rule "gathered
      iff work ratio ≥ crossover" is exactly this cost comparison);
    * pruned    — the gathered cost × ``survivor_frac / PRUNE_DISCOUNT``
      (only when the caller supplies ``survivor_frac``, its block-max
      estimate of the surviving work fraction): pruning pays bound +
      seed-pass overhead, so the estimate must undercut
      :data:`PRUNE_DISCOUNT` before pruning is worth it.

    A batch with no postings at all is trivially gathered (nothing to
    scan beats scanning everything). Cost ties keep the previous regime
    ordering (gathered beats blocked at equality, matching the pre-pruned
    planner exactly when ``survivor_frac`` is None).

    ``plan="device"`` records that the fragment table is built on device —
    its descriptor-build cost is then free on the host, so the DEFAULT
    crossover is scaled by :data:`DEVICE_PLAN_DISCOUNT` (an explicit
    ``crossover`` is always used verbatim).
    """
    if regime not in ("auto", "blocked", "gathered", "pruned"):
        raise ValueError(f"unknown regime {regime!r}")
    if plan not in ("host", "device"):
        raise ValueError(f"unknown plan mode {plan!r}")
    if crossover is None:
        c = DEFAULT_CROSSOVER * (DEVICE_PLAN_DISCOUNT if plan == "device"
                                 else 1.0)
    else:
        c = float(crossover)
    ratio = nnz / max(sum_df, 1)
    if regime != "auto":
        chosen, forced = regime, True
    elif sum_df == 0:
        chosen, forced = "gathered", False
    else:
        costs = {"gathered": c * sum_df, "blocked": float(nnz)}
        if survivor_frac is not None:
            costs["pruned"] = (c * sum_df * float(survivor_frac)
                               / PRUNE_DISCOUNT)
        # first-listed wins ties: gathered over blocked (the pre-pruned
        # rule), either existing regime over pruned (cheaper machinery)
        chosen = min(costs, key=lambda r: (costs[r],
                                           list(costs).index(r)))
        forced = False
    return RetrievalPlan(regime=chosen, sum_df=int(sum_df), nnz=int(nnz),
                         work_ratio=float(ratio), crossover=c,
                         forced=forced, plan=plan,
                         survivor_frac=survivor_frac)


def validate_query_batch(query_tokens, n_vocab: int, *,
                         counters: dict | None = None,
                         on_invalid: str = "sanitize") -> list[np.ndarray]:
    """The ONE query sanitizer every retriever entry point shares.

    Client batches arrive ragged and occasionally malformed; the kernels
    downstream assume clean int32 token ids in ``[0, n_vocab)``. This
    normalizes each entry to a 1-D int32 array, handling:

    * ``None`` / empty entries        -> empty queries (scored as such);
    * multi-dimensional arrays        -> raveled (``_pack_batch`` did this
      silently already; now it is counted);
    * float dtypes with integral data -> recast (dtype drift from JSON or
      feature pipelines);
    * non-integral floats / NaN       -> those tokens dropped;
    * out-of-range / negative ids     -> those tokens dropped.

    Every repair increments ``counters`` (keys ``dropped_tokens``,
    ``recast_queries``, ``raveled_queries``, ``null_queries``) so engine
    ``health()`` reports can expose a misbehaving client instead of
    silently absorbing it. ``on_invalid="raise"`` surfaces
    :class:`repro.serve.errors.InvalidQueryError` on the FIRST defect
    instead of repairing (strict serving mode). Exactness: dropping a
    token the index cannot score is the only behavior-preserving repair —
    a valid token is never altered, so sanitized results equal the
    results on the valid sub-batch exactly.
    """
    if on_invalid not in ("sanitize", "raise"):
        raise ValueError(f"unknown on_invalid mode {on_invalid!r}")
    c = counters if counters is not None else {}

    def bump(key, n=1):
        c[key] = c.get(key, 0) + n

    def bad(msg):
        from repro.serve.errors import InvalidQueryError
        raise InvalidQueryError(msg)

    out = []
    for i, q in enumerate(query_tokens):
        if q is None:
            if on_invalid == "raise":
                bad(f"query {i} is None")
            bump("null_queries")
            out.append(np.zeros(0, np.int32))
            continue
        a = np.asarray(q)
        if a.ndim != 1:
            if on_invalid == "raise" and a.ndim > 1:
                bad(f"query {i} has shape {a.shape}; expected 1-D token ids")
            if a.ndim > 1:
                bump("raveled_queries")
            a = a.ravel()
        if a.dtype.kind == "f":
            finite = np.isfinite(a)
            integral = finite & (a == np.floor(a))
            if not integral.all():
                if on_invalid == "raise":
                    bad(f"query {i} has non-integral or non-finite "
                        f"token ids (dtype {a.dtype})")
                bump("dropped_tokens", int((~integral).sum()))
                a = a[integral]
            if on_invalid == "raise" and a.dtype.kind == "f":
                # integral float batches are recoverable drift, allowed
                # even in strict mode — only lossy repairs raise
                pass
            bump("recast_queries")
            a = a.astype(np.int64)
        elif a.dtype.kind == "b":
            bump("recast_queries")
            a = a.astype(np.int64)
        elif a.dtype.kind not in ("i", "u"):
            if on_invalid == "raise":
                bad(f"query {i} has non-numeric dtype {a.dtype}")
            bump("dropped_tokens", int(a.size))
            a = np.zeros(0, np.int64)
        ok = (a >= 0) & (a < n_vocab)
        if not ok.all():
            if on_invalid == "raise":
                lo = int(a.min()) if a.size else 0
                hi = int(a.max()) if a.size else 0
                bad(f"query {i} token ids must be in [0, {n_vocab}); "
                    f"got range [{lo}, {hi}]")
            bump("dropped_tokens", int((~ok).sum()))
            a = a[ok]
        out.append(a.astype(np.int32, copy=False))
    return out


def default_doc_ids(vis_blocks: np.ndarray, k: int, n_docs: int,
                    block_size: int) -> np.ndarray:
    """First ``k`` doc ids from blocks a batch never visited.

    The resident kernel only scores documents in VISITED blocks; every doc
    in an unvisited block has raw score exactly 0 (no posting touched it),
    so any ``k`` of them serve as the default-document candidates the
    splice needs (mirror of :func:`missing_doc_ids`, but block-granular —
    the fragment plan already knows the visited-block set). Entries ``>=
    n_docs`` mean fewer than ``k`` unvisited docs exist; callers mask them.

    Fully vectorized, O(k log nv) — the j-th-missing trick of
    :func:`missing_doc_ids` applied at block granularity (``vis_blocks``
    is sorted unique, so ``vis[i] - i`` counts the unvisited blocks below
    ``vis[i]``). NOT O(n_blocks) and no per-block Python loop: this sits
    on the resident serving hot path and shards can have 10^5 blocks.
    """
    out = np.full(k, n_docs, dtype=np.int32)
    if k <= 0 or n_docs <= 0:
        return out
    vis = np.asarray(vis_blocks, dtype=np.int64)
    n_blocks = -(-n_docs // block_size)
    # first k unvisited block ids (each supplies ≥1 doc id, so k suffice)
    j = np.arange(min(k, n_blocks), dtype=np.int64)
    unvis = j + np.searchsorted(vis - np.arange(vis.size), j + 1)
    unvis = unvis[unvis < n_blocks]
    if unvis.size == 0:
        return out
    lo = unvis * block_size
    cnt = np.minimum(lo + block_size, n_docs) - lo
    cum = np.cumsum(cnt)
    cut = int(np.searchsorted(cum, k)) + 1        # blocks that reach k ids
    lo, cnt, cum = lo[:cut], cnt[:cut], cum[:cut]
    total = int(cum[-1])
    flat = np.repeat(lo, cnt) + (np.arange(total, dtype=np.int64)
                                 - np.repeat(cum - cnt, cnt))
    take = min(k, total)
    out[:take] = flat[:take].astype(np.int32)
    return out


def topk_numpy(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Paper's np.argpartition path (introspective selection, O(n) average)."""
    k = min(k, scores.shape[-1])
    part = np.argpartition(scores, -k, axis=-1)[..., -k:]
    vals = np.take_along_axis(scores, part, axis=-1)
    order = np.argsort(-vals, axis=-1, kind="stable")
    idx = np.take_along_axis(part, order, axis=-1)
    return idx, np.take_along_axis(scores, idx, axis=-1)


def merge_topk(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side global merge of per-shard candidate lists (the paper's
    two-stage top-k, stage 2).

    ``parts`` is an iterable of ``(ids, scores)`` arrays — each a shard's
    local top-k. One concatenate + ``argpartition`` (average-O(n) selection)
    replaces the per-candidate Python heap: the candidate count is
    ``shards × k``, tiny, but the vectorized path keeps the serving engine's
    merge off the interpreter even at large fan-in.
    """
    pairs = [(np.asarray(i), np.asarray(s)) for i, s in parts]
    if k <= 0 or not pairs or sum(i.size for i, _ in pairs) == 0:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float32))
    ids = np.concatenate([i.astype(np.int64, copy=False) for i, _ in pairs])
    scores = np.concatenate([s for _, s in pairs]).astype(np.float64,
                                                          copy=False)
    k = min(k, ids.size)
    part = np.argpartition(scores, -k)[-k:]
    order = np.argsort(-scores[part], kind="stable")
    sel = part[order]
    return ids[sel], scores[sel].astype(np.float32)


def merge_topk_batch(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Batched stage-2 merge: per-shard ``(ids [B, k_s], scores [B, k_s])``
    candidate lists -> global ``(ids [B, k], scores [B, k])``.

    The batched counterpart of :func:`merge_topk`: one concatenate along
    the candidate axis + one row-wise ``argpartition`` serves the whole
    query batch — the serving engine's ``retrieve_batch`` merge stays a
    single vectorized pass no matter the fan-in or batch size.
    """
    pairs = [(np.asarray(i), np.asarray(s)) for i, s in parts]
    # batch dim from the materialized pairs — `parts` may be a one-shot
    # iterable and is already consumed by the comprehension above
    b = max((i.shape[0] for i, _ in pairs), default=0)
    pairs = [(i, s) for i, s in pairs if i.size]
    if k <= 0 or not pairs:
        return (np.zeros((b, 0), np.int64), np.zeros((b, 0), np.float32))
    ids = np.concatenate([i.astype(np.int64, copy=False) for i, _ in pairs],
                         axis=1)
    sc = np.concatenate([s for _, s in pairs], axis=1).astype(np.float64,
                                                              copy=False)
    k = min(k, ids.shape[1])
    part = np.argpartition(sc, -k, axis=1)[:, -k:]
    vals = np.take_along_axis(sc, part, axis=1)
    order = np.argsort(-vals, axis=1, kind="stable")
    sel = np.take_along_axis(part, order, axis=1)
    return (np.take_along_axis(ids, sel, axis=1),
            np.take_along_axis(sc, sel, axis=1).astype(np.float32))


def splice_default_docs(cand_vals: jax.Array, cand_ids: jax.Array,
                        candidates: jax.Array, k: int, n_docs: int, *,
                        valid: jax.Array | None = None,
                        doc_limit=None,
                        default_ids: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Merge candidate winners with ``k`` DEFAULT documents per query.

    A document outside the candidate set contributes no posting, so its
    exact raw score is 0 (the §2.1 nonoccurrence shift is a per-query
    constant added later). Those defaults matter whenever a matched doc
    scores *below* zero (robertson IDF) or fewer than ``k`` docs match —
    the full-scan kernel gets this free by touching every doc; here
    :func:`missing_doc_ids` recovers ``k`` non-candidate ids in
    O(k log C) without ever scanning ``n_docs``. The single definition of
    the splice — the host (``ops.bm25_retrieve_gathered``), resident
    (``ops.bm25_retrieve_resident``) and sharded
    (:func:`_device_gathered_topk`) paths must not diverge.

    ``cand_vals``/``cand_ids`` are ``[B, m]`` candidate winners (raw
    scores); ``candidates`` the sorted candidate table with ``valid``
    marking real entries (see :func:`missing_doc_ids`); ``doc_limit``
    (default ``n_docs``, may be traced) masks fabricated ids at/above it
    to -inf — pass the shard's REAL doc count when arrays are padded.
    ``default_ids`` (``[k]``) short-circuits the j-th-missing computation
    when the caller already holds ``k`` known-default ids (the resident
    path's unvisited-block defaults, :func:`default_doc_ids`) —
    ``candidates`` may then be None. Returns ``(ids [B, k], raw values
    [B, k])``.
    """
    if doc_limit is None:
        doc_limit = n_docs
    b = cand_vals.shape[0]
    miss = (missing_doc_ids(candidates, k, n_docs, valid=valid)
            if default_ids is None else default_ids)
    def_v = jnp.where(miss < doc_limit, 0.0,
                      jnp.finfo(cand_vals.dtype).min).astype(cand_vals.dtype)
    all_v = jnp.concatenate(
        [cand_vals, jnp.broadcast_to(def_v[None], (b, k))], axis=1)
    all_i = jnp.concatenate(
        [cand_ids, jnp.broadcast_to(miss[None], (b, k))], axis=1)
    mvals, midx = jax.lax.top_k(all_v, k)
    return jnp.take_along_axis(all_i, midx, axis=-1), mvals


def missing_doc_ids(candidates: jax.Array, k: int, n_docs: int, *,
                    valid: jax.Array | None = None) -> jax.Array:
    """First ``k`` doc ids NOT in a sorted candidate list (the j-th missing
    element trick, O(k log C)).

    ``candidates`` is sorted ascending over its valid prefix; ``valid``
    marks real entries (default: ``candidates >= 0``, matching the
    ``GatheredPostings`` candidate table's -1 padding; the device gather
    passes ``candidates < INT32_MAX`` instead). ``missing_before[i] =
    candidates[i] - i`` counts the doc ids below ``candidates[i]`` that
    are absent; the j-th missing id (0-based) is then
    ``j + searchsorted(missing_before, j + 1)``. Returned entries ``>=
    n_docs`` mean fewer than ``k`` ids are missing — callers mask them.
    """
    if valid is None:
        valid = candidates >= 0
    n = candidates.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    miss_before = jnp.where(valid, candidates - iota, n_docs + 1)
    j = jnp.arange(k, dtype=jnp.int32)
    return j + jnp.searchsorted(miss_before, j + 1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def topk_jax(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """XLA top_k (the paper's preferred backend). Returns (indices, values)."""
    vals, idx = jax.lax.top_k(scores, k)
    return idx, vals


def blockwise_topk(scores: jax.Array, k: int, block: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Two-stage single-device top-k: per-block top-k, then merge.

    Lossless: every global winner is a winner of its own block. Average work
    is O(n) + O((n/block)·k log ...) — the distributed merge in miniature,
    and the jnp oracle for ``kernels/blockwise_topk``.
    """
    n = scores.shape[-1]
    assert n % block == 0, (n, block)
    nb = n // block
    kb = min(k, block)
    blocks = scores.reshape(*scores.shape[:-1], nb, block)
    bvals, bidx = jax.lax.top_k(blocks, kb)            # [..., nb, kb]
    base = (jnp.arange(nb, dtype=jnp.int32) * block)[:, None]
    gidx = (bidx + base).reshape(*scores.shape[:-1], nb * kb)
    gvals = bvals.reshape(*scores.shape[:-1], nb * kb)
    mvals, midx = jax.lax.top_k(gvals, min(k, nb * kb))
    return jnp.take_along_axis(gidx, midx, axis=-1), mvals


def _device_gathered_topk(indptr, doc_ids, scores, nonocc, q_tokens,
                          q_weights, n_docs_true, *, p_max: int, k: int,
                          n_docs: int):
    """Shard-local query-driven gather → candidate top-k, all on device.

    The device half of the inverted-index regime (run descriptors computed
    ON DEVICE from the CSC ``indptr`` — no host round-trip inside the
    sharded step):

    1. batch-unique token table (``jnp.unique`` with a static size);
    2. per-token posting-run descriptors ``(start, len)`` from ``indptr``;
    3. one flattened gather of the runs into a static ``p_max`` budget —
       work O(Σ df over batch-unique tokens), shared across the B queries
       instead of per-query like ``score_query``'s ragged gather;
    4. candidate compaction (``jnp.unique`` over gathered doc ids) and a
       segment-sum into a ``[p_max, B]`` candidate accumulator — never
       O(n_docs);
    5. per-query top-k over candidates + default-document splice (a doc
       outside the candidate set scores exactly the §2.1 shift; the j-th
       missing-id trick finds k such ids in O(k log C)).

    ``n_docs`` is the static PADDED per-shard doc count (array sizing);
    ``n_docs_true`` the shard's real count (traced scalar) — the default
    splice only fabricates ids below it, so uneven shards never emit
    phantom padding documents.

    Returns ``(ids [B, kk], scores [B, kk], overflow [] bool)`` with
    ``kk = min(k, n_docs)``; overflow is True iff the batch's posting
    demand exceeded the static ``p_max`` bucket (results are then lower
    bounds — callers retry at a larger bucket). The unique-token table
    needs no overflow flag: its size is min(B·Q, |V|), an upper bound on
    the batch's distinct tokens by construction.
    """
    b, q = q_tokens.shape
    u_max = min(b * q, int(indptr.shape[0]) - 1)
    big = jnp.iinfo(jnp.int32).max
    kk = min(k, n_docs)

    flat_q = jnp.where(q_tokens >= 0, q_tokens, big).reshape(-1)
    uniq = jnp.unique(flat_q, size=u_max, fill_value=big)        # sorted
    valid_u = uniq < big
    safe_u = jnp.where(valid_u, uniq, 0)
    starts = indptr[safe_u]
    lens = jnp.where(valid_u, indptr[safe_u + 1] - starts, 0)    # run descrs

    cum = jnp.cumsum(lens)
    total = cum[-1]
    j = jnp.arange(p_max, dtype=jnp.int32)
    owner = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    owner = jnp.minimum(owner, u_max - 1)
    off_excl = cum[owner] - lens[owner]
    pos = starts[owner] + (j - off_excl)
    ok = j < total
    g_doc = jnp.where(ok, doc_ids[pos], big)
    g_sc = jnp.where(ok, scores[pos], 0.0)

    # per-query weight column for each unique token (scatter; pads add 0)
    qpos = jnp.clip(jnp.searchsorted(uniq, jnp.where(q_tokens >= 0,
                                                     q_tokens, 0)),
                    0, u_max - 1)
    table = jnp.zeros((u_max, b), scores.dtype).at[
        qpos, jnp.broadcast_to(jnp.arange(b)[:, None], (b, q))
    ].add(q_weights)
    contrib = g_sc[:, None] * jnp.take(table, owner, axis=0)     # [p_max, B]

    # candidate compaction: distinct docs ≤ total ≤ p_max when not
    # overflowing, so c_max = p_max needs no extra overflow condition
    cand = jnp.unique(g_doc, size=p_max, fill_value=big)
    slot = jnp.searchsorted(cand, g_doc).astype(jnp.int32)
    cand_scores = jax.ops.segment_sum(contrib, slot,
                                      num_segments=p_max + 1)[:p_max]
    valid_c = cand < big
    masked = jnp.where(valid_c[:, None], cand_scores,
                       jnp.finfo(cand_scores.dtype).min)
    vals, ci = jax.lax.top_k(masked.T, kk)                       # [B, kk]
    ids = jnp.take(cand, ci)

    # default-doc splice (ids absent from the candidate set, raw score 0);
    # ids at/past the shard's REAL doc count are padding, masked to -inf
    ids, mvals = splice_default_docs(vals, ids, cand, kk, n_docs,
                                     valid=valid_c, doc_limit=n_docs_true)

    valid_qt = q_tokens >= 0
    shift = jnp.sum(jnp.where(valid_qt,
                              nonocc[jnp.where(valid_qt, q_tokens, 0)], 0.0)
                    * q_weights, axis=-1)
    return ids, mvals + shift[:, None], total > p_max


def make_sharded_retrieve(mesh: Mesh, shard_axes: tuple[str, ...], *,
                          p_max: int, k: int, n_docs_per_shard: int,
                          return_overflow: bool = False,
                          gathered: bool = False):
    """Build the pod-scale retrieval step: shard-local score+topk, global merge.

    The device index arrays are sharded over ``shard_axes`` (leading dim =
    shard id); queries are replicated. Returns a jit-able
    ``retrieve(stacked_index, q_tokens[B,Q], q_weights[B,Q])``
    -> (global doc ids [B,k], scores [B,k]). With ``return_overflow=True``
    a third ``[B]`` bool output marks queries whose posting demand exceeded
    ``p_max`` on ANY shard (their scores are lower bounds — mirror of
    ``score_batch(..., return_overflow=True)``).

    ``gathered=True`` swaps the shard-local step for the query-driven
    device gather (:func:`_device_gathered_topk`): posting-run descriptors
    from ``indptr``, one batch-shared gather, candidate-compacted
    accumulation — O(Σ df) instead of a per-query O(p_max)+O(n_docs)
    segment-sum. The overflow flag is then batch-global (the gather is
    batch-shared), broadcast to ``[B]`` for a uniform interface;
    :func:`sharded_retrieve_adaptive` wraps it with larger-bucket retries.
    """
    def local_score_topk(idx_arrays, q_tokens, q_weights):
        # idx_arrays leaves have a leading shard dim of size 1 inside shard_map
        indptr, doc_ids, scores, nonocc, offsets, counts = (
            x[0] for x in idx_arrays)
        if gathered:
            gidx, vals, over = _device_gathered_topk(
                indptr, doc_ids, scores, nonocc, q_tokens, q_weights,
                counts[0], p_max=p_max, k=k, n_docs=n_docs_per_shard)
            gidx = gidx + offsets.astype(jnp.int32)
            over = jnp.broadcast_to(over, (q_tokens.shape[0],))
            return gidx[None], vals[None], over[None]
        dindex = DeviceIndex(indptr, doc_ids, scores, nonocc,
                             n_docs=n_docs_per_shard, doc_offset=0)
        s, over = jax.vmap(
            lambda t, w: score_query(dindex, t, w, p_max=p_max))(
            q_tokens, q_weights)                        # [B, n_local], [B]
        # docs past the shard's REAL count exist only as stacking padding
        # (uneven shards): a padded doc would score the bare nonoccurrence
        # shift and could displace real winners — mask before selecting.
        local = jnp.arange(s.shape[-1], dtype=jnp.int32)
        s = jnp.where(local[None, :] < counts[0], s,
                      jnp.finfo(s.dtype).min)
        vals, local_idx = jax.lax.top_k(s, min(k, n_docs_per_shard))
        gidx = local_idx + offsets.astype(jnp.int32)
        return gidx[None], vals[None], over[None]       # keep shard dim

    spec_idx = tuple(P(shard_axes) for _ in range(6))

    @jax.jit
    def retrieve(idx_arrays, q_tokens, q_weights):
        # check_rep: the gathered step's jnp.unique lowers to a scan whose
        # carry trips shard_map's replication checker on replicated query
        # operands (a checker false positive) — the computation itself is
        # shard-local either way.
        gidx, gvals, gover = shard_map(
            local_score_topk, mesh=mesh,
            in_specs=(spec_idx, P(), P()),
            out_specs=(P(shard_axes), P(shard_axes), P(shard_axes)),
            check_rep=not gathered,
        )(idx_arrays, q_tokens, q_weights)
        # [n_shards, B, k] -> [B, n_shards*k] -> global top-k (the merge)
        b = q_tokens.shape[0]
        allv = jnp.swapaxes(gvals, 0, 1).reshape(b, -1)
        alli = jnp.swapaxes(gidx, 0, 1).reshape(b, -1)
        mvals, midx = jax.lax.top_k(allv, k)
        ids = jnp.take_along_axis(alli, midx, axis=-1)
        if return_overflow:
            return ids, mvals, jnp.any(gover, axis=0)
        return ids, mvals

    return retrieve


def sharded_retrieve_adaptive(mesh: Mesh, shard_axes: tuple[str, ...], *,
                              k: int, n_docs_per_shard: int,
                              p_floor: int = 1024, gathered: bool = True):
    """Adaptive-budget wrapper: overflow becomes a larger-bucket RETRY.

    The static ``p_max`` of :func:`make_sharded_retrieve` silently truncates
    postings when a batch's Σ df exceeds it — score corruption. This wrapper
    sizes the budget as power-of-two buckets starting at ``p_floor`` (one
    compiled variant per bucket, cached here): if the overflow flag fires,
    the batch re-runs at the next bucket until it fits or the bucket covers
    the shard's whole posting array (Σ df ≤ nnz always, so that final
    bucket cannot overflow on the posting budget). Typical traffic settles
    into one bucket after warmup and never recompiles again.

    The retry is CAPPED, not open-ended: if the overflow flag somehow
    persists at the Σdf-covering bucket (which indicates a flag/metadata
    bug, not legitimate demand), the wrapper raises
    :class:`repro.serve.errors.PlanOverflowError` carrying the attempted
    bucket trail instead of returning silently-truncated scores.

    Returns ``retrieve(idx_arrays, q_tokens, q_weights) ->
    (ids [B,k], scores [B,k], p_max_used)``.
    """
    from .scoring import bucket_pow2

    cache: dict[int, object] = {}
    state = {"p": p_floor}    # last successful bucket — the steady state

    def retrieve(idx_arrays, q_tokens, q_weights):
        nnz_pad = int(idx_arrays[1].shape[-1])
        cap = bucket_pow2(nnz_pad, floor=p_floor)
        # start at the last bucket that fit, NOT p_floor: steady-state
        # traffic above the floor must execute ONCE per call, not once per
        # smaller bucket (compilation caching alone doesn't buy that).
        p = min(state["p"], cap)
        attempted = []
        while True:
            fn = cache.get(p)
            if fn is None:
                fn = cache[p] = make_sharded_retrieve(
                    mesh, shard_axes, p_max=p, k=k,
                    n_docs_per_shard=n_docs_per_shard,
                    return_overflow=True, gathered=gathered)
            ids, vals, over = fn(idx_arrays, q_tokens, q_weights)
            attempted.append(p)
            if not bool(np.any(np.asarray(over))):
                state["p"] = p
                return ids, vals, p
            if p >= cap:
                from repro.serve.errors import PlanOverflowError
                raise PlanOverflowError(
                    "posting-budget overflow persists at the Σdf-covering "
                    f"bucket: attempted p_max buckets {attempted} "
                    f"(cap {cap}, shard nnz_pad {nnz_pad}) — the overflow "
                    "flag at the cap indicates corrupt index metadata, "
                    "not query demand", attempted=attempted, cap=cap)
            p = min(p * 2, cap)

    return retrieve


def stack_shard_arrays(shards, mesh: Mesh, shard_axes: tuple[str, ...]):
    """Host → device: stack per-shard index arrays padded to common sizes.

    Returns the 6-tuple consumed by ``make_sharded_retrieve`` with every
    leaf sharded over ``shard_axes`` on its leading (shard) dim, plus the
    static (padded) per-shard doc count. The last leaf carries each
    shard's REAL doc count so the retrieval step can mask the stacking
    padding (uneven shards) instead of scoring phantom documents.
    """
    n = len(shards)
    v = shards[0].n_vocab
    nnz_pad = max(s.doc_ids.size for s in shards)
    ndoc_pad = max(s.doc_lens.size for s in shards)
    indptr = np.zeros((n, v + 1), np.int32)
    doc_ids = np.zeros((n, nnz_pad), np.int32)
    scores = np.zeros((n, nnz_pad), np.float32)
    nonocc = np.zeros((n, v), np.float32)
    offsets = np.zeros((n, 1), np.int32)
    counts = np.zeros((n, 1), np.int32)
    for i, s in enumerate(shards):
        indptr[i] = s.indptr
        doc_ids[i, : s.doc_ids.size] = s.doc_ids
        # padding postings point at doc 0 with score 0 — harmless
        scores[i, : s.scores.size] = s.scores
        nonocc[i] = s.nonoccurrence
        offsets[i, 0] = s.doc_offset
        counts[i, 0] = s.doc_lens.size
    sharding = NamedSharding(mesh, P(shard_axes))
    arrs = tuple(jax.device_put(a, sharding)
                 for a in (indptr, doc_ids, scores, nonocc, offsets, counts))
    return arrs, ndoc_pad
