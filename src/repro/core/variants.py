"""The BM25 scoring variants reproduced by BM25S (Kamphuis et al., 2020).

Each variant is expressed in the *eager* form used by the paper: the full
contribution ``S(t, D)`` of token ``t`` to document ``D`` is computable at
index time from ``(tf, df, N, dl, L_avg)`` alone.

Sparse variants (``S(t,D) = 0`` whenever ``TF(t,D) = 0``):
    robertson, atire, lucene (the BM25S default)

Shifted variants (§2.1 of the paper — a non-zero *nonoccurrence score*
``S⁰(t) = S(t, ∅)`` exists, so the index stores the differential
``SΔ(t,D) = S(t,D) − S⁰(t)`` and retrieval adds ``Σᵢ S⁰(qᵢ)`` back):
    bm25l  (Lv & Zhai 2011),  bm25+  (Lv & Zhai 2011),
    tfldp  (TF_{l∘δ∘p}×IDF, Rousseau & Vazirgiannis 2013)

All functions are NumPy-vectorized and run host-side at index time; nothing
here touches JAX. Shapes: ``tf, dl`` are per-posting arrays, ``df`` is
per-posting (df of that posting's token) for ``score`` and per-token for
``idf``/``nonoccurrence``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

Array = np.ndarray


@dataclass(frozen=True)
class BM25Params:
    k1: float = 1.5
    b: float = 0.75
    delta: float = 0.5  # bm25l / tfldp default; bm25+ conventionally uses 1.0
    method: str = "lucene"


def _len_norm(dl: Array, l_avg: float, k1: float, b: float) -> Array:
    """k1 * (1 - b + b * |D| / L_avg) — the denominator's document part."""
    return k1 * (1.0 - b + b * dl / l_avg)


# --------------------------------------------------------------------------
# IDF definitions
# --------------------------------------------------------------------------

def idf_robertson(df: Array, n_docs: int) -> Array:
    return np.log((n_docs - df + 0.5) / (df + 0.5))


def idf_lucene(df: Array, n_docs: int) -> Array:
    # ln(1 + (N - df + 0.5)/(df + 0.5)) — always positive; the paper's eq.
    return np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))


def idf_atire(df: Array, n_docs: int) -> Array:
    return np.log(n_docs / df)


def idf_bm25l(df: Array, n_docs: int) -> Array:
    return np.log((n_docs + 1.0) / (df + 0.5))


def idf_bm25plus(df: Array, n_docs: int) -> Array:
    return np.log((n_docs + 1.0) / df)


# --------------------------------------------------------------------------
# Variant definitions
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BM25Variant:
    name: str
    idf: Callable[[Array, int], Array]
    is_shifted: bool

    def score(self, tf: Array, df: Array, n_docs: int, dl: Array,
              l_avg: float, p: BM25Params) -> Array:
        """Eager score S(t, D) for postings with tf > 0."""
        raise NotImplementedError

    def nonoccurrence(self, df: Array, n_docs: int, p: BM25Params) -> Array:
        """S⁰(t) = S(t, ∅): the score of a token absent from the document."""
        return np.zeros_like(df, dtype=np.float64)


class _Robertson(BM25Variant):
    def score(self, tf, df, n_docs, dl, l_avg, p):
        return self.idf(df, n_docs) * tf / (tf + _len_norm(dl, l_avg, p.k1, p.b))


class _Lucene(BM25Variant):
    def score(self, tf, df, n_docs, dl, l_avg, p):
        return self.idf(df, n_docs) * tf / (tf + _len_norm(dl, l_avg, p.k1, p.b))


class _ATIRE(BM25Variant):
    def score(self, tf, df, n_docs, dl, l_avg, p):
        return (self.idf(df, n_docs) * (p.k1 + 1.0) * tf
                / (tf + _len_norm(dl, l_avg, p.k1, p.b)))


class _BM25L(BM25Variant):
    def score(self, tf, df, n_docs, dl, l_avg, p):
        c = tf / (1.0 - p.b + p.b * dl / l_avg)
        return (self.idf(df, n_docs) * (p.k1 + 1.0) * (c + p.delta)
                / (p.k1 + c + p.delta))

    def nonoccurrence(self, df, n_docs, p):
        # c = 0 when tf = 0
        return (self.idf(df, n_docs) * (p.k1 + 1.0) * p.delta
                / (p.k1 + p.delta))


class _BM25Plus(BM25Variant):
    def score(self, tf, df, n_docs, dl, l_avg, p):
        return self.idf(df, n_docs) * (
            (p.k1 + 1.0) * tf / (_len_norm(dl, l_avg, p.k1, p.b) + tf)
            + p.delta
        )

    def nonoccurrence(self, df, n_docs, p):
        return self.idf(df, n_docs) * p.delta


class _TFldp(BM25Variant):
    """TF_{l∘δ∘p} × IDF: 1 + ln(1 + ln(tf/(1-b+b·dl/L) + δ))."""

    def score(self, tf, df, n_docs, dl, l_avg, p):
        tfp = tf / (1.0 - p.b + p.b * dl / l_avg)
        return self.idf(df, n_docs) * (1.0 + np.log(1.0 + np.log(tfp + p.delta)))

    def nonoccurrence(self, df, n_docs, p):
        return self.idf(df, n_docs) * (1.0 + np.log(1.0 + np.log(p.delta)))


VARIANTS: dict[str, BM25Variant] = {
    "robertson": _Robertson("robertson", idf_robertson, is_shifted=False),
    "lucene": _Lucene("lucene", idf_lucene, is_shifted=False),
    "atire": _ATIRE("atire", idf_atire, is_shifted=False),
    "bm25l": _BM25L("bm25l", idf_bm25l, is_shifted=True),
    "bm25+": _BM25Plus("bm25+", idf_bm25plus, is_shifted=True),
    "bm25plus": _BM25Plus("bm25+", idf_bm25plus, is_shifted=True),
    "tfldp": _TFldp("tfldp", idf_bm25plus, is_shifted=True),
}


def get_variant(name: str) -> BM25Variant:
    try:
        return VARIANTS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown BM25 variant {name!r}; available: {sorted(set(VARIANTS))}"
        ) from None


def dense_score_matrix(tf_matrix: Array, n_docs: int, dl: Array,
                       variant: BM25Variant, p: BM25Params) -> Array:
    """Oracle: the full dense |V| × |C| score matrix, computed lazily.

    Used only by tests/benchmarks on small corpora to pin down exactness of
    the eager-sparse (+ shifted) implementations. ``tf_matrix`` is dense
    ``|V| × |C|`` term frequencies.
    """
    df = (tf_matrix > 0).sum(axis=1).astype(np.float64)
    l_avg = float(dl.mean())
    out = np.zeros_like(tf_matrix, dtype=np.float64)
    s0 = variant.nonoccurrence(np.maximum(df, 1.0), n_docs, p)
    # nonoccurrence applies to every (t, D) with tf == 0 (and df > 0)
    out += np.where(df[:, None] > 0, s0[:, None], 0.0)
    rows, cols = np.nonzero(tf_matrix)
    if rows.size:
        out[rows, cols] = variant.score(
            tf_matrix[rows, cols].astype(np.float64),
            df[rows], n_docs, dl[cols].astype(np.float64), l_avg, p,
        )
    return out
