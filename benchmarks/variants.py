"""Table 3 — variant / parameter comparison on the planted-relevance corpus.

Reproduces the paper's observations structurally:
  * all variants land in a narrow NDCG band;
  * ATIRE and BM25+ at (k1=1.2, b=0.75, δ=1) produce near-identical
    rankings (their scores differ by a rank-preserving transform when IDFs
    align);
  * the (k1, b) sweep spans the recommended ranges.
"""

from __future__ import annotations

import numpy as np

from repro.core import BM25Retriever
from repro.data.corpus import SyntheticCorpus, ndcg_at_k

SETTINGS = [
    ("lucene", 1.5, 0.75, 0.5),
    ("lucene", 1.2, 0.75, 0.5),
    ("lucene", 0.9, 0.40, 0.5),
    ("robertson", 1.2, 0.75, 0.5),
    ("atire", 1.2, 0.75, 0.5),
    ("bm25+", 1.2, 0.75, 1.0),
    ("bm25l", 1.2, 0.75, 0.5),
    ("tfldp", 1.2, 0.75, 0.5),
]


def run(n_docs: int = 800, n_queries: int = 60, k: int = 10) -> list[dict]:
    base = SyntheticCorpus(n_docs=n_docs, n_topics=16, vocab_size=900,
                           seed=11)
    queries, qrels = base.queries_with_qrels(n_queries)
    rows = []
    rankings = {}
    for method, k1, b, delta in SETTINGS:
        r = BM25Retriever(method=method, k1=k1, b=b, delta=delta
                          ).index(base.documents)
        ids, _ = r.retrieve(queries, k=k)
        ids = np.asarray(ids)
        rankings[(method, k1)] = ids
        ndcg = float(np.mean([
            ndcg_at_k(ids[i], qrels[i], k) for i in range(len(queries))
        ]))
        rows.append({"variant": method, "k1": k1, "b": b,
                     "ndcg@10": round(ndcg, 4)})
    # paper's ATIRE == BM25+ observation: top-k overlap
    a, b_ = rankings[("atire", 1.2)], rankings[("bm25+", 1.2)]
    overlap = float(np.mean([
        len(set(a[i]) & set(b_[i])) / a.shape[1] for i in range(a.shape[0])
    ]))
    rows.append({"variant": "atire~bm25+_topk_overlap", "k1": 1.2,
                 "b": 0.75, "ndcg@10": round(overlap, 4)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
