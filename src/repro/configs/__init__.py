"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures + the paper's own (``bm25s``). Each module
exposes ``CONFIG`` (exact published config), ``SMOKE`` (reduced same-family
variant for CPU tests), ``FAMILY`` and ``cells()`` (the dry-run /
benchmark cells for its assigned input shapes).
"""

from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "h2o-danube3-4b": "h2o_danube3_4b",
    "gemma3-1b": "gemma3_1b",
    "qwen3-8b": "qwen3_8b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "egnn": "egnn",
    "autoint": "autoint",
    "mind": "mind",
    "dlrm-mlperf": "dlrm_mlperf",
    "sasrec": "sasrec",
    "bm25s": "bm25s",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "bm25s"]


def _norm(name: str) -> str:
    return name.replace("_", "-").replace("h2o-danube-3", "h2o-danube3")


def get_module(arch: str):
    key = _norm(arch)
    if key not in _ARCH_MODULES:
        raise ValueError(f"unknown arch {arch!r}; available: "
                         f"{sorted(_ARCH_MODULES)}")
    return importlib.import_module(f".{_ARCH_MODULES[key]}", __package__)


def get_config(arch: str):
    return get_module(arch).CONFIG


def get_smoke(arch: str):
    return get_module(arch).SMOKE


def get_cells(arch: str):
    return get_module(arch).cells()


def all_cells(include_extra: bool = True):
    archs = list(_ARCH_MODULES) if include_extra else ASSIGNED_ARCHS
    out = []
    for a in archs:
        out.extend(get_cells(a))
    return out


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)
