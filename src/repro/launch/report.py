"""Aggregate dryrun.json into the EXPERIMENTS.md §Dry-run/§Roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--json benchmarks/out/dryrun.json]
"""

from __future__ import annotations

import argparse
import json


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{1e3 * x:.1f}m"
    return f"{1e6 * x:.0f}u"


def roofline_table(results: dict, mesh: str) -> str:
    rows = []
    hdr = ("| arch/shape | kind | compute s | memory s | collective s | "
           "bottleneck | useful ratio | roofline frac | mem GiB/dev |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for key in sorted(results):
        r = results[key]
        if not r.get("ok") or r["mesh"] != mesh:
            continue
        mem = (r["memory"].get("argument_size_b", 0)
               + r["memory"].get("temp_size_b", 0))
        rows.append(
            f"| {r['arch']}/{r['shape']} | {r['kind']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {fmt_bytes(mem)} |")
    return "\n".join(rows)


def dryrun_table(results: dict) -> str:
    rows = ["| arch/shape | mesh | compile s | HLO GFLOP/dev | HLO GiB/dev |"
            " coll GiB/dev | collectives (count) |",
            "|" + "---|" * 7]
    for key in sorted(results):
        r = results[key]
        if not r.get("ok"):
            continue
        colls = ", ".join(f"{op}:{d['count']}"
                          for op, d in sorted(r["collectives"].items()))
        rows.append(
            f"| {r['arch']}/{r['shape']} | {r['mesh']} | {r['compile_s']:.0f}"
            f" | {r['hlo_flops_per_device'] / 1e9:.1f}"
            f" | {fmt_bytes(r['hlo_bytes_per_device'])}"
            f" | {fmt_bytes(r['collective_wire_bytes_per_device'])}"
            f" | {colls or '-'} |")
    return "\n".join(rows)


def summarize(results: dict) -> dict:
    ok = [r for r in results.values() if r.get("ok")]
    per_mesh = {}
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in ok if r["mesh"] == mesh]
        per_mesh[mesh] = {
            "cells": len(sub),
            "bottlenecks": {b: sum(1 for r in sub if r["bottleneck"] == b)
                            for b in ("compute", "memory", "collective")},
            "worst_fraction": sorted(
                ((r["roofline_fraction"], f"{r['arch']}/{r['shape']}")
                 for r in sub))[:5],
            "most_collective_bound": sorted(
                ((r["collective_s"] / max(r["step_time_bound_s"], 1e-30),
                  r["collective_s"], f"{r['arch']}/{r['shape']}")
                 for r in sub), reverse=True)[:5],
        }
    return per_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/out/dryrun.json")
    ap.add_argument("--mode", choices=["roofline", "dryrun", "summary"],
                    default="summary")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    if args.mode == "roofline":
        print(roofline_table(results, args.mesh))
    elif args.mode == "dryrun":
        print(dryrun_table(results))
    else:
        print(json.dumps(summarize(results), indent=1, default=str))


if __name__ == "__main__":
    main()
