"""Logical-axis sharding: models annotate, meshes decide.

Model code names *logical* axes — ``"dp"`` (all data-parallel mesh axes:
``"pod"`` and/or ``"data"``) and ``"model"`` (tensor parallelism) — and this
module resolves them against whatever physical mesh is active:

* :func:`activation_sharding` pushes a mesh onto a stack for the duration of
  a ``with`` block; :func:`constrain` is a NO-OP outside any such block, so
  the exact same model code traces on a laptop CPU and on a 512-chip pod.
* Resolution is divisibility-checked per dimension: an axis whose size does
  not divide the dimension is silently dropped (replicated) instead of
  failing, which is what makes elastic meshes (6 devices, 4 heads on an
  8-way model axis, ...) Just Work.

``batch_pspec`` / ``param_pspecs`` are the generic placement rules used by
cells that have no architecture-specific sharding (the LM family overrides
them with ``configs.common.lm_param_pspecs``).
"""

from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Data-parallel logical axis -> these physical axes (in mesh-major order).
_DP_AXES = ("pod", "data")

_MESH_STACK: list[Mesh] = []


@contextlib.contextmanager
def activation_sharding(mesh: Mesh):
    """Activate ``mesh`` for :func:`constrain` / :func:`dp_spmd_axes`."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def _active_mesh() -> Mesh | None:
    return _MESH_STACK[-1] if _MESH_STACK else None


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh's non-trivial data-parallel axes (subset of pod/data)."""
    return tuple(a for a in _DP_AXES if mesh.shape.get(a, 1) > 1)


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(math.prod(mesh.shape[a] for a in axes)) if axes else 1


def _dp_entry(mesh: Mesh) -> str | tuple[str, ...] | None:
    axes = data_axes(mesh)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def dp_spmd_axes() -> str | tuple[str, ...] | None:
    """``spmd_axis_name`` for ``jax.vmap`` over the data-parallel axes.

    ``None`` when no mesh is active or the active mesh has no data axes —
    ``vmap(spmd_axis_name=None)`` is the ordinary unsharded vmap.
    """
    mesh = _active_mesh()
    if mesh is None:
        return None
    return _dp_entry(mesh)


def _resolve(mesh: Mesh, dim: int, name: str | None):
    """Logical axis name -> physical spec entry, divisibility-checked."""
    if name is None:
        return None
    if name == "dp":
        axes = data_axes(mesh)
        if not axes or dim % _axes_size(mesh, axes) != 0:
            return None
        return axes[0] if len(axes) == 1 else axes
    size = mesh.shape.get(name, 1)
    return name if size > 1 and dim % size == 0 else None


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names, one per dim.

    No-op outside an :func:`activation_sharding` block. Unresolvable axes
    (absent from the mesh, size 1, or not dividing the dimension) become
    ``None`` (replicated) rather than errors.
    """
    mesh = _active_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(
            f"constrain got {len(axes)} axis names for rank-{x.ndim} array")
    spec = P(*(_resolve(mesh, d, a) for d, a in zip(x.shape, axes)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_pspec(shape, mesh: Mesh) -> P:
    """Batch placement: leading dim over the data axes when divisible."""
    shape = tuple(shape)
    axes = data_axes(mesh)
    if not shape or not axes:
        return P()
    n = _axes_size(mesh, axes)
    if shape[0] > 0 and shape[0] % n == 0:
        return P(_dp_entry(mesh), *([None] * (len(shape) - 1)))
    return P()


def param_pspecs(params_shapes, mesh: Mesh):
    """Generic ZeRO-ish parameter placement for architecture-less cells.

    Shards the first dimension divisible by the data-axes size; everything
    else replicates. Any placement is numerically correct — this one just
    bounds per-device parameter memory for the non-LM cells.
    """
    axes = data_axes(mesh)
    n = _axes_size(mesh, axes)
    entry = _dp_entry(mesh)

    def one(leaf) -> P:
        shape = tuple(getattr(leaf, "shape", ()))
        spec: list = [None] * len(shape)
        if entry is not None:
            for i, d in enumerate(shape):
                if d >= n and d % n == 0:
                    spec[i] = entry
                    break
        return P(*spec)

    return jax.tree.map(one, params_shapes)
