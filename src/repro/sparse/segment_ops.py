"""Segment reductions — the shared sparse primitive (DESIGN.md §2).

JAX has no EmbeddingBag and only BCOO sparse; message passing, embedding
bags and BM25 scoring are all built here on ``jax.ops.segment_sum`` /
``segment_max`` over explicit index arrays. These wrappers add the
conventions the rest of the framework relies on (sentinel segments for
padding, mean/softmax composites, degree normalization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(values: jax.Array, segment_ids: jax.Array, num_segments: int
                ) -> jax.Array:
    """segment_sum with an extra sentinel row: ids == num_segments are dropped."""
    out = jax.ops.segment_sum(values, segment_ids, num_segments=num_segments + 1)
    return out[:num_segments]


def segment_mean(values: jax.Array, segment_ids: jax.Array, num_segments: int,
                 *, eps: float = 1e-9) -> jax.Array:
    s = segment_sum(values, segment_ids, num_segments)
    ones = jnp.ones(values.shape[:1], dtype=values.dtype)
    cnt = segment_sum(ones, segment_ids, num_segments)
    return s / jnp.maximum(cnt, eps)[(...,) + (None,) * (s.ndim - 1)]


def segment_max(values: jax.Array, segment_ids: jax.Array, num_segments: int
                ) -> jax.Array:
    out = jax.ops.segment_max(values, segment_ids,
                              num_segments=num_segments + 1)
    return out[:num_segments]


def segment_softmax(logits: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Softmax normalized within each segment (GAT-style edge softmax)."""
    m = segment_max(logits, segment_ids, num_segments)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    shifted = logits - m[segment_ids]
    e = jnp.exp(shifted)
    z = segment_sum(e, segment_ids, num_segments)
    return e / jnp.maximum(z[segment_ids], 1e-9)


def scatter_add(acc: jax.Array, idx: jax.Array, values: jax.Array) -> jax.Array:
    """acc[idx] += values with out-of-range idx dropped (XLA scatter-add)."""
    return acc.at[idx].add(values, mode="drop")


def one_hot_matmul_segment_sum(values: jax.Array, segment_ids: jax.Array,
                               num_segments: int) -> jax.Array:
    """Scatter-add expressed as a dense one-hot matmul (the MXU form).

    ``out[s] = Σ_p 1[segment_ids[p] == s] · values[p]`` — mathematically the
    same as segment_sum but lowered to a GEMM. Used as the jnp-level
    reference for the Pallas block kernels and, on TPU, as the fast path for
    small ``num_segments`` (e.g. one document block).
    """
    oh = (segment_ids[:, None] ==
          jnp.arange(num_segments, dtype=segment_ids.dtype)[None, :])
    oh = oh.astype(values.dtype)
    if values.ndim == 1:
        return values @ oh
    return jnp.einsum("p...,ps->s...", values, oh)
