"""mixtral-8x22b [arXiv:2401.04088]: the heavyweight MoE cell (141B params).

56L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=32768,
8 experts top-2, SWA 4096. Parameters + optimizer state only fit through
the FSDP-style (data+model) weight sharding; training uses 8 microbatches.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig, reduced
from .common import lm_cells

CONFIG = LMConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    sliding_window=4096,
    n_experts=8, top_k=2, capacity_factor=1.25, moe_group_seq=4096,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = reduced(CONFIG, moe_group_seq=16)

FAMILY = "lm"
N_MICROBATCHES = 8


def cells():
    return lm_cells("mixtral-8x22b", CONFIG, n_microbatches=N_MICROBATCHES)
