"""Eager index-time scoring — the core of BM25S (§2 of the paper).

``build_index`` turns a tokenized corpus into a :class:`BM25Index`: every
possible score any future query token can contribute to any document is
computed *now* and stored sparsely, CSC-style keyed by token id. For the
shifted variants (§2.1) the stored value is the differential
``SΔ(t,D) = S(t,D) − S⁰(t)`` and the per-token nonoccurrence vector ``S⁰``
is kept alongside (a |V| array — footnote 12 of the paper).

Query-time work is thereby reduced to: gather the postings of the query
tokens, sum per document, (+ the scalar ``Σ S⁰(qᵢ)`` for shifted variants),
then top-k. See ``scoring.py`` / ``retrieval.py`` for the device-side half.

Everything in this module is host-side NumPy; it is embarrassingly parallel
over document shards (each shard indexes its own documents given global
``df``/``L_avg`` statistics — see ``build_sharded_indexes``).
"""

from __future__ import annotations

import functools
import json
import os
from dataclasses import dataclass, replace
from typing import Sequence

import numpy as np

from .variants import BM25Params, BM25Variant, get_variant


@dataclass
class CorpusStats:
    """Global statistics needed to eagerly score any document shard."""

    n_docs: int
    n_vocab: int
    df: np.ndarray        # [V] int64 document frequency
    l_avg: float          # mean document length (tokens)

    @staticmethod
    def from_corpus(doc_tokens: Sequence[np.ndarray], n_vocab: int) -> "CorpusStats":
        tok, _doc, _tf, doc_lens = _corpus_coo(doc_tokens, n_vocab)
        return CorpusStats.from_coo(tok, doc_lens, len(doc_tokens), n_vocab)

    @staticmethod
    def from_coo(tok: np.ndarray, doc_lens: np.ndarray, n_docs: int,
                 n_vocab: int) -> "CorpusStats":
        """Stats straight from a ``_corpus_coo`` result — each (doc, token)
        pair appears once there, so ``df`` is a bincount of the token
        column. Lets ``build_index`` share one COO pass for stats + scores.
        """
        df = np.bincount(tok, minlength=n_vocab).astype(np.int64)
        l_avg = float(doc_lens.sum()) / max(n_docs, 1)
        return CorpusStats(n_docs=n_docs, n_vocab=n_vocab, df=df,
                           l_avg=l_avg)


@dataclass
class BM25Index:
    """Eager sparse score index in CSC-by-token layout.

    ``indptr[t] : indptr[t+1]`` delimits the postings of token ``t``;
    ``doc_ids`` are sorted ascending within each token's slice (the CSC
    invariant the distributed/blocked layouts rely on).
    """

    indptr: np.ndarray      # [V+1] int64
    doc_ids: np.ndarray     # [nnz] int32
    scores: np.ndarray      # [nnz] float32 — S or SΔ (differential)
    nonoccurrence: np.ndarray  # [V] float32 — S⁰; zeros for sparse variants
    doc_lens: np.ndarray    # [C] int32
    n_docs: int
    n_vocab: int
    l_avg: float
    variant: str
    params: BM25Params
    doc_offset: int = 0     # global id of local doc 0 (for shards)

    @property
    def nnz(self) -> int:
        return int(self.doc_ids.size)

    @functools.cached_property
    def is_shifted(self) -> bool:
        # cached: the O(V) scan runs once per index, not per property access
        # (dataclasses.replace builds a fresh instance, so shard/reshard
        # copies re-derive it from their own nonoccurrence array).
        return bool(np.any(self.nonoccurrence != 0.0))

    def token_df(self) -> np.ndarray:
        return np.diff(self.indptr)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "arrays.npz"),
            indptr=self.indptr, doc_ids=self.doc_ids, scores=self.scores,
            nonoccurrence=self.nonoccurrence, doc_lens=self.doc_lens,
        )
        meta = {
            "n_docs": self.n_docs, "n_vocab": self.n_vocab,
            "l_avg": self.l_avg, "variant": self.variant,
            "doc_offset": self.doc_offset,
            "params": {"k1": self.params.k1, "b": self.params.b,
                       "delta": self.params.delta, "method": self.params.method},
        }
        tmp = os.path.join(path, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "meta.json"))

    @staticmethod
    def load(path: str) -> "BM25Index":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        arrs = np.load(os.path.join(path, "arrays.npz"))
        return BM25Index(
            indptr=arrs["indptr"], doc_ids=arrs["doc_ids"],
            scores=arrs["scores"], nonoccurrence=arrs["nonoccurrence"],
            doc_lens=arrs["doc_lens"], n_docs=meta["n_docs"],
            n_vocab=meta["n_vocab"], l_avg=meta["l_avg"],
            variant=meta["variant"], doc_offset=meta.get("doc_offset", 0),
            params=BM25Params(**meta["params"]),
        )


def _corpus_coo(doc_tokens: Sequence[np.ndarray], n_vocab: int
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(token_ids, doc_ids, tf) postings + doc lengths for a corpus shard.

    One flattened pass: concatenate all documents, encode each occurrence as
    the scalar key ``doc·V + token``, and let a single ``np.unique`` produce
    the distinct (doc, token) pairs with their term frequencies — no
    per-document Python loop, no per-document ``np.unique`` call overhead.
    Keys stay int32 when ``n_docs·V`` fits (the sort is ~2x faster there).
    Output is sorted by (doc, token); ``build_index`` re-sorts CSC-by-token.
    """
    n = len(doc_tokens)
    doc_lens = np.fromiter((t.size for t in doc_tokens), dtype=np.int64,
                           count=n).astype(np.int32)
    nnz_total = int(doc_lens.sum())
    if n == 0 or nnz_total == 0:
        z64, zf = np.zeros(0, np.int64), np.zeros(0, np.float64)
        return z64, z64.copy(), zf, doc_lens
    flat = np.concatenate(doc_tokens)
    lo, hi = int(flat.min()), int(flat.max())
    if lo < 0 or hi >= n_vocab:
        # the key encoding would silently wrap an out-of-range token into a
        # neighboring document's postings — fail loudly instead (the seed's
        # per-doc path raised IndexError here). InvalidQueryError inherits
        # ValueError, so pre-taxonomy callers keep working.
        from repro.serve.errors import InvalidQueryError
        raise InvalidQueryError(
            f"token ids must be in [0, {n_vocab}); corpus has [{lo}, {hi}]")
    if n * n_vocab < 2 ** 31:
        flat_tok = flat.astype(np.int32, copy=False)
        flat_doc = np.repeat(np.arange(n, dtype=np.int32), doc_lens)
        key = flat_doc * np.int32(n_vocab) + flat_tok
    else:
        flat_tok = flat.astype(np.int64, copy=False)
        flat_doc = np.repeat(np.arange(n, dtype=np.int64),
                             doc_lens.astype(np.int64))
        key = flat_doc * n_vocab + flat_tok
    uniq_key, tf = np.unique(key, return_counts=True)
    tok = (uniq_key % n_vocab).astype(np.int64)
    doc = (uniq_key // n_vocab).astype(np.int64)
    return tok, doc, tf.astype(np.float64), doc_lens


def build_index(
    doc_tokens: Sequence[np.ndarray],
    n_vocab: int,
    *,
    params: BM25Params | None = None,
    stats: CorpusStats | None = None,
    doc_offset: int = 0,
) -> BM25Index:
    """Eagerly score a (shard of a) corpus into a :class:`BM25Index`.

    ``stats`` carries *global* corpus statistics; when ``None`` they are
    computed from ``doc_tokens`` itself (single-shard build). Passing global
    stats while giving only a document shard is exactly how the distributed
    index build works — scores depend on other shards only through
    ``(df, N, L_avg)``.
    """
    params = params or BM25Params()
    variant: BM25Variant = get_variant(params.method)
    tok, doc, tf, doc_lens = _corpus_coo(doc_tokens, n_vocab)
    if stats is None:
        # single-shard build: stats come from the same COO pass (the seed
        # walked the corpus twice — once for df, once for postings)
        stats = CorpusStats.from_coo(tok, doc_lens, len(doc_tokens), n_vocab)

    df_per_posting = stats.df[tok].astype(np.float64)
    dl_per_posting = doc_lens[doc].astype(np.float64)
    scores = variant.score(
        tf, df_per_posting, stats.n_docs, dl_per_posting, stats.l_avg, params
    )

    # §2.1 score shifting: store the differential score so the matrix stays
    # sparse. For sparse variants nonocc ≡ 0 and this is a no-op.
    df_all = stats.df.astype(np.float64)
    nonocc = np.where(
        df_all > 0,
        variant.nonoccurrence(np.maximum(df_all, 1.0), stats.n_docs, params),
        0.0,
    )
    scores = scores - nonocc[tok]

    # CSC-by-token: sort postings by (token, doc). np.lexsort is stable.
    order = np.lexsort((doc, tok))
    tok, doc, scores = tok[order], doc[order], scores[order]
    indptr = np.zeros(n_vocab + 1, dtype=np.int64)
    np.add.at(indptr, tok + 1, 1)
    np.cumsum(indptr, out=indptr)

    return BM25Index(
        indptr=indptr,
        doc_ids=doc.astype(np.int32),
        scores=scores.astype(np.float32),
        nonoccurrence=nonocc.astype(np.float32),
        doc_lens=doc_lens,
        n_docs=stats.n_docs if doc_offset == 0 and len(doc_tokens) == stats.n_docs
        else len(doc_tokens),
        n_vocab=n_vocab,
        l_avg=stats.l_avg,
        variant=variant.name,
        params=params,
        doc_offset=doc_offset,
    )


def build_sharded_indexes(
    doc_tokens: Sequence[np.ndarray],
    n_vocab: int,
    n_shards: int,
    *,
    params: BM25Params | None = None,
) -> list[BM25Index]:
    """Distributed index build: global stats pass + per-shard eager scoring.

    Shards are contiguous document ranges (balanced ±1). This mirrors the
    production flow where each host indexes its own documents after an
    all-reduce of ``(df, Σ len, N)``.
    """
    stats = CorpusStats.from_corpus(doc_tokens, n_vocab)
    bounds = np.linspace(0, len(doc_tokens), n_shards + 1).astype(int)
    shards = []
    for s in range(n_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        shards.append(
            build_index(doc_tokens[lo:hi], n_vocab, params=params,
                        stats=stats, doc_offset=lo)
        )
    return shards


def reshard_index(shards: list[BM25Index], n_new: int) -> list[BM25Index]:
    """Elastically re-balance shards to a new shard count.

    Pure host-side re-slicing: postings are re-bucketed by global doc id
    with ONE global sort. Each posting's destination shard comes from a
    ``searchsorted`` against the new shard bounds; a single
    ``lexsort((doc, token, shard))`` then makes every new shard a contiguous
    slice already in CSC (token-major) order — no per-shard boolean masks
    over the full posting set, no per-shard re-sorts. Used when the device
    pool shrinks/grows (see serve/engine.py).
    """
    if not shards:
        raise ValueError("no shards to reshard")
    # reconstruct global COO
    v = shards[0].n_vocab
    tok = np.concatenate([
        np.repeat(np.arange(v, dtype=np.int64), np.diff(sh.indptr))
        for sh in shards])
    doc = np.concatenate([sh.doc_ids.astype(np.int64) + sh.doc_offset
                          for sh in shards])
    sc = np.concatenate([sh.scores for sh in shards])
    n_docs_total = max(sh.doc_offset + sh.doc_lens.size for sh in shards)
    doc_lens = np.zeros(n_docs_total, dtype=np.int32)
    for sh in shards:
        doc_lens[sh.doc_offset: sh.doc_offset + sh.doc_lens.size] = sh.doc_lens

    bounds = np.linspace(0, n_docs_total, n_new + 1).astype(np.int64)
    shard_of = np.searchsorted(bounds, doc, side="right") - 1
    order = np.lexsort((doc, tok, shard_of))
    tok, doc, sc, shard_of = (tok[order], doc[order], sc[order],
                              shard_of[order])
    starts = np.searchsorted(shard_of, np.arange(n_new + 1, dtype=np.int64))

    proto = shards[0]
    out = []
    for s in range(n_new):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        p0, p1 = int(starts[s]), int(starts[s + 1])
        t_s, d_s, s_s = tok[p0:p1], doc[p0:p1] - lo, sc[p0:p1]
        indptr = np.zeros(v + 1, dtype=np.int64)
        np.cumsum(np.bincount(t_s, minlength=v), out=indptr[1:])
        out.append(replace(
            proto,
            indptr=indptr, doc_ids=d_s.astype(np.int32),
            scores=s_s.astype(np.float32), doc_lens=doc_lens[lo:hi],
            n_docs=hi - lo, doc_offset=lo,
        ))
    return out
