"""BM25S tokenizer: scikit-learn regex split + stopwords + Snowball stemming.

Faithful to §2 of the paper:

* splitting uses the exact scikit-learn ``CountVectorizer`` token pattern
  ``r"(?u)\\b\\w\\w+\\b"``;
* optional stopword removal (Elastic English list);
* optional Snowball stemming, applied to the *vocabulary* ("we can stem all
  words in the vocabulary, which can be used to look up the stemmed version
  of each word in the collection") — i.e. each unique surface form is stemmed
  once and occurrences are mapped through a dict;
* finally each (stemmed) unique word maps to an integer id, so documents and
  queries become ``int32`` arrays usable to index score matrices.

Everything here is host-side NumPy/Python — devices only ever see the ids.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from .stemmer import snowball_stem
from .stopwords import get_stopwords

TOKEN_PATTERN = re.compile(r"(?u)\b\w\w+\b")


@dataclass
class Vocabulary:
    """Bidirectional word<->id mapping over (optionally stemmed) word forms."""

    word_to_id: dict[str, int] = field(default_factory=dict)
    frozen: bool = False

    def lookup(self, word: str) -> int:
        """Return id for ``word``, adding it if the vocab is not frozen."""
        wid = self.word_to_id.get(word, -1)
        if wid < 0 and not self.frozen:
            wid = len(self.word_to_id)
            self.word_to_id[word] = wid
        return wid

    def __len__(self) -> int:
        return len(self.word_to_id)

    @property
    def id_to_word(self) -> list[str]:
        out = [""] * len(self.word_to_id)
        for w, i in self.word_to_id.items():
            out[i] = w
        return out


@dataclass
class Tokenizer:
    """Configurable BM25S analyzer.

    Parameters mirror the paper's Table 2 ablation axes: ``stopwords`` in
    {"english", None} and ``stemmer`` in {"snowball", None}.
    """

    stopwords: str | None = "english"
    stemmer: str | None = "snowball"
    lower: bool = True

    def __post_init__(self) -> None:
        self._stop = get_stopwords(self.stopwords)
        self._stem_cache: dict[str, str] = {}
        self.vocab = Vocabulary()

    # -- single text ---------------------------------------------------------
    def split(self, text: str) -> list[str]:
        if self.lower:
            text = text.lower()
        return TOKEN_PATTERN.findall(text)

    def _stem(self, word: str) -> str:
        stemmed = self._stem_cache.get(word)
        if stemmed is None:
            stemmed = snowball_stem(word)
            self._stem_cache[word] = stemmed
        return stemmed

    def tokenize_words(self, text: str) -> list[str]:
        words = [w for w in self.split(text) if w not in self._stop]
        if self.stemmer is not None:
            words = [self._stem(w) for w in words]
        return words

    def tokenize_ids(self, text: str, *, update_vocab: bool = True) -> np.ndarray:
        """Tokenize to int32 ids. Unknown words map to -1 when vocab frozen."""
        was_frozen = self.vocab.frozen
        if not update_vocab:
            self.vocab.frozen = True
        try:
            ids = [self.vocab.lookup(w) for w in self.tokenize_words(text)]
        finally:
            self.vocab.frozen = was_frozen
        ids = [i for i in ids if i >= 0]
        return np.asarray(ids, dtype=np.int32)

    # -- corpus --------------------------------------------------------------
    def tokenize_corpus(self, texts: Iterable[str]) -> list[np.ndarray]:
        """Tokenize a corpus, growing the vocabulary."""
        return [self.tokenize_ids(t, update_vocab=True) for t in texts]

    def tokenize_queries(self, texts: Sequence[str]) -> list[np.ndarray]:
        """Tokenize queries against the frozen corpus vocabulary.

        Out-of-vocabulary query words are dropped: they cannot match any
        document, so their score contribution is exactly zero for the sparse
        variants, and they contribute only the query-constant ``S⁰`` shift
        for the shifted variants (handled by the retriever).
        """
        return [self.tokenize_ids(t, update_vocab=False) for t in texts]

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)
