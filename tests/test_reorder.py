"""Build-time doc-id reordering (``sparse.reorder``) — exactness first.

Pins the reordering contract at every layer:

* **sparse** — ``signature_permutation`` is a valid, deterministic
  permutation (a pure function of the index, for the snapshot recovery
  rung); ``permute_index``/``unpermute_index`` round-trip BIT-exactly and
  preserve the permutation-invariant arrays (``indptr``,
  ``nonoccurrence``) and the CSC doc-ascending invariant; the sort-free
  scipy signature path and the pure-numpy fallback produce identical
  signatures; ``remap_board`` is the identity off score ties and pins
  ascending client-id order inside bit-equal ties.
* **serve** — a reordered pruned retriever is BIT-identical (exact float
  equality) to the reordered resident oracle sharing its layout, on all
  five BM25 variants, both bound dtypes and both planners, including
  empty queries and k ≥ n_docs; scores match ``ScipyBM25`` to the same
  1e-4 the unordered device paths are held to, and every returned id
  provably achieves its score. Serving a reordered index never moves
  MORE device bytes than the random-order path — postings byte-equal,
  descriptors can only shrink (the id remap is one host gather on the
  winner board).
* **engine** — a reordered scorer serves exactly through
  ``RetrievalEngine`` (client-order global ids), survives a ragged
  rescale, and donor adoption honours the permutation: identical
  postings + identical perm adopt, perm mismatch rebuilds.
"""

import numpy as np
import pytest

from conftest import (HAVE_HYPOTHESIS, given, make_corpus, settings, st)
from repro.core import (BM25Params, ScipyBM25, build_index,
                        build_sharded_indexes, dense_oracle_scores,
                        topk_numpy)
from repro.serve import DeviceRetriever, RetrievalEngine
from repro.sparse.block_csr import (TRANSFERS, DeviceIndex,
                                    reset_transfer_stats)
from repro.sparse.reorder import (REORDER_MODES, doc_signatures,
                                  invert_permutation, is_permutation,
                                  minhash_signatures, permutations_equal,
                                  permute_index, remap_board,
                                  signature_permutation, unpermute_index)

# transfer-byte equalities asserted here change legitimately when a chaos
# fault forces a ladder hop (an extra host-gather upload)
pytestmark = pytest.mark.no_chaos

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, frag=8, q_max=8)


def _reordered_oracle(idx, **kw):
    """Unpruned single-buffer resident path on the SAME permuted layout —
    the bit-exactness comparator (f32 reduction order is a property of
    the layout, so only a same-layout oracle can be compared bitwise)."""
    return DeviceRetriever(idx, regime="gathered", gather="resident",
                           double_buffer=False, acc_block=16,
                           reorder="signature", **SMALL, **kw)


def make_clustered_corpus(rng, n_docs=300, n_vocab=60):
    """Half the docs spike on token 0, half on token 1 — a signature sort
    separates the two populations into disjoint blocks."""
    corpus = []
    for d in range(n_docs):
        base = rng.integers(2, n_vocab, size=10).astype(np.int32)
        hot = d % 2
        tf = 20 if d % 30 == 0 else 3
        corpus.append(np.concatenate(
            [np.full(tf, hot, np.int32), base]))
    rng.shuffle(corpus)
    return corpus


# -- sparse: permutation construction ----------------------------------------

def test_signature_permutation_valid_and_deterministic(rng):
    corpus = make_clustered_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params())
    for mode in ("signature", "minhash"):
        p1 = signature_permutation(idx, mode=mode)
        p2 = signature_permutation(idx, mode=mode)
        assert p1 is not None and is_permutation(p1, 300)
        np.testing.assert_array_equal(p1, p2)
    assert signature_permutation(idx, mode="none") is None
    with pytest.raises(ValueError):
        signature_permutation(idx, mode="zorder")
    assert set(REORDER_MODES) == {"none", "signature", "minhash"}


def test_signature_permutation_degenerate_cases():
    one = build_index([np.array([0, 1], np.int32)], 4, params=BM25Params())
    assert signature_permutation(one) is None          # n_docs <= 1
    empty = build_index([np.zeros(0, np.int32) for _ in range(4)], 4,
                        params=BM25Params())
    # all-empty docs: identical (sentinel) signatures, stable sort keeps
    # client order -> identity -> None
    assert signature_permutation(empty) is None


def test_doc_signatures_scipy_and_numpy_paths_identical(rng, monkeypatch):
    corpus = make_corpus(rng, n_docs=80, n_vocab=40)
    idx = build_index(corpus, 40, params=BM25Params(method="robertson"))
    fast = doc_signatures(idx)

    import builtins
    real_import = builtins.__import__

    def no_scipy(name, *a, **k):
        if name.startswith("scipy"):
            raise ImportError(name)
        return real_import(name, *a, **k)

    monkeypatch.setattr(builtins, "__import__", no_scipy)
    slow = doc_signatures(idx)
    np.testing.assert_array_equal(fast, slow)


def test_doc_signatures_shape_and_sentinel(rng):
    # doc 0 has a single posting: columns 1.. hold the n_vocab sentinel
    corpus = [np.array([3], np.int32)] + \
        [rng.integers(0, 10, size=8).astype(np.int32) for _ in range(5)]
    idx = build_index(corpus, 10, params=BM25Params())
    sig = doc_signatures(idx)
    assert sig.shape == (6, 4)
    assert sig[0, 0] == 3 and (sig[0, 1:] == 10).all()


def test_minhash_signatures_nondegenerate(rng):
    """Zipf-ish head token present in every doc must not collapse all
    signatures to one value (hash(0) != 0 under every function)."""
    corpus = [np.concatenate([np.zeros(2, np.int32),
                              rng.integers(1, 50, size=8).astype(np.int32)])
              for _ in range(40)]
    idx = build_index(corpus, 50, params=BM25Params())
    sig = minhash_signatures(idx)
    assert np.unique(sig[:, 0]).size > 1


def test_invert_and_is_permutation():
    perm = np.array([2, 0, 3, 1], np.int32)
    inv = invert_permutation(perm)
    np.testing.assert_array_equal(perm[inv], np.arange(4))
    np.testing.assert_array_equal(inv[perm], np.arange(4))
    assert is_permutation(perm, 4)
    assert is_permutation(np.zeros(0, np.int32), 0)
    assert not is_permutation(perm, 5)                # wrong length
    assert not is_permutation(np.array([0, 0, 1, 2]), 4)   # duplicate
    assert not is_permutation(np.array([0, 1, 2, 4]), 4)   # out of range
    assert not is_permutation(perm.reshape(2, 2), 4)       # wrong ndim
    assert permutations_equal(None, None)
    assert not permutations_equal(perm, None)
    assert permutations_equal(perm, perm.copy())
    assert not permutations_equal(perm, inv)


# -- sparse: permuting an index ----------------------------------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_permute_roundtrip_bit_exact(method, rng):
    corpus = make_corpus(rng, n_docs=70, n_vocab=30)
    corpus[3] = np.zeros(0, np.int32)                 # posting-less doc
    idx = build_index(corpus, 30, params=BM25Params(method=method))
    perm = signature_permutation(idx)
    assert perm is not None
    idx_p = permute_index(idx, perm)
    back = unpermute_index(idx_p, perm)
    np.testing.assert_array_equal(back.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(back.scores, idx.scores)
    np.testing.assert_array_equal(back.doc_lens, idx.doc_lens)
    np.testing.assert_array_equal(back.indptr, idx.indptr)


def test_permute_preserves_invariants(rng):
    corpus = make_corpus(rng, n_docs=50, n_vocab=25)
    idx = build_index(corpus, 25, params=BM25Params())
    perm = signature_permutation(idx)
    idx_p = permute_index(idx, perm)
    # per-token arrays are permutation-invariant
    np.testing.assert_array_equal(idx_p.indptr, idx.indptr)
    np.testing.assert_array_equal(idx_p.nonoccurrence, idx.nonoccurrence)
    # CSC invariant: doc ids strictly ascending within every token run
    for t in range(25):
        run = idx_p.doc_ids[idx.indptr[t]:idx.indptr[t + 1]]
        assert (np.diff(run) > 0).all()
    # every doc keeps its exact score vector, just under a new id
    inv = invert_permutation(perm)
    sc = ScipyBM25(idx)
    sc_p = ScipyBM25(idx_p)
    q = np.arange(25, dtype=np.int32)
    np.testing.assert_array_equal(sc.score(q), sc_p.score(q)[inv])


def test_permute_empty_and_stripped_index():
    idx = build_index([np.zeros(0, np.int32) for _ in range(6)], 8,
                      params=BM25Params())
    perm = np.array([5, 4, 3, 2, 1, 0], np.int32)
    idx_p = permute_index(idx, perm)                  # nnz == 0 early path
    assert idx_p.doc_ids.size == 0
    np.testing.assert_array_equal(idx_p.doc_lens, idx.doc_lens[perm])


# -- sparse: the merge remap --------------------------------------------------

def test_remap_board_identity_off_ties():
    perm = np.array([3, 1, 0, 2], np.int32)
    ids = np.array([[0, 2, 1]], np.int64)
    board = np.array([[5.0, 3.0, 1.0]], np.float32)
    out = remap_board(ids, board, perm)
    np.testing.assert_array_equal(out, [[3, 0, 1]])   # plain gather


def test_remap_board_canonicalizes_tie_runs():
    """Inside a bit-equal score tie the remapped ids come back ascending
    by CLIENT id, independent of the device-local order the permuted
    layout produced."""
    perm = np.array([9, 8, 7, 6, 5], np.int32)
    board = np.array([[2.0, 1.0, 1.0, 1.0, 0.5]], np.float32)
    ids = np.array([[0, 3, 1, 2, 4]], np.int64)
    out = remap_board(ids, board, perm)
    np.testing.assert_array_equal(out, [[9, 6, 7, 8, 5]])
    # empty boards (batch of empty queries at k=0) pass through
    empty = remap_board(np.zeros((1, 0), np.int64),
                        np.zeros((1, 0), np.float32), perm)
    assert empty.shape == (1, 0)


# -- serve: bit-identical to the same-layout oracle ---------------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
@pytest.mark.parametrize("bmax_dtype", ["f32", "u8"])
def test_reordered_pruned_bit_identical(method, bmax_dtype, rng):
    corpus = make_clustered_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params(method=method))
    oracle = _reordered_oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", bmax_dtype=bmax_dtype,
                             reorder="signature", **SMALL)
    assert pruned.dindex.perm is not None
    queries = [np.array([0], np.int32),
               rng.integers(0, 60, size=4).astype(np.int32),
               np.zeros(0, np.int32)]                 # empty query in-batch
    for k in (1, 9, 300):                             # incl. k == n_docs
        i0, v0 = oracle.retrieve_batch(queries, k)
        i1, v1 = pruned.retrieve_batch(queries, k)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(i0, i1)
    # and the scores are the true BM25 scores under CLIENT ids
    sc = ScipyBM25(idx)
    i1, v1 = pruned.retrieve_batch(queries, 9)
    for i, q in enumerate(queries):
        np.testing.assert_allclose(sc.score(q)[i1[i]], v1[i], atol=1e-4)


def test_reordered_device_plan_bit_identical(rng):
    corpus = make_clustered_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params())
    oracle = _reordered_oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", plan="device", bmax_dtype="u8",
                             reorder="signature", **SMALL)
    queries = [np.array([0], np.int32),
               rng.integers(0, 60, size=5).astype(np.int32)]
    for k in (1, 4):
        i0, v0 = oracle.retrieve_batch(queries, k)
        i1, v1 = pruned.retrieve_batch(queries, k)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(i0, i1)


def test_reordered_vs_unordered_same_answers(rng):
    """Across layouts only scores-to-1e-4 holds (f32 reduction order is
    layout-dependent); ids must agree wherever the score order is
    unambiguous at f32."""
    corpus = make_clustered_corpus(rng, n_docs=200, n_vocab=50)
    idx = build_index(corpus, 50, params=BM25Params(method="lucene"))
    plain = DeviceRetriever(idx, regime="pruned", **SMALL)
    reord = DeviceRetriever(idx, regime="pruned", reorder="signature", **SMALL)
    queries = [rng.integers(0, 50, size=4).astype(np.int32)
               for _ in range(3)]
    i0, v0 = plain.retrieve_batch(queries, 7)
    i1, v1 = reord.retrieve_batch(queries, 7)
    np.testing.assert_allclose(v0, v1, atol=1e-4)
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        full = sc.score(q)
        # each returned id achieves the oracle score at its rank (ids may
        # differ from the unordered run only inside f32-level ties)
        np.testing.assert_allclose(full[i1[i]], full[i0[i]], atol=2e-4)


def test_reorder_moves_zero_extra_device_bytes(rng):
    """Posting bytes byte-equal; descriptor bytes never larger (clustering
    can shrink the fragment table — a token's postings land in fewer
    blocks — but the host-gather remap must never add device traffic)."""
    corpus = make_clustered_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params())
    plain = DeviceRetriever(idx, regime="pruned", **SMALL)
    reord = DeviceRetriever(idx, regime="pruned", reorder="signature", **SMALL)
    queries = [rng.integers(0, 60, size=4).astype(np.int32)]

    def batch_bytes(r):
        r.retrieve_batch(queries, 5)                  # warm / compile
        reset_transfer_stats()
        r.retrieve_batch(queries, 5)
        return TRANSFERS.posting_bytes, TRANSFERS.descriptor_bytes

    post_p, desc_p = batch_bytes(plain)
    post_r, desc_r = batch_bytes(reord)
    assert post_r == post_p
    assert desc_r <= desc_p


def test_reorder_raises_skip_rate_on_clustered_corpus(rng):
    """The point of the whole exercise: separable populations -> strictly
    more fragments pruned/skipped than random order."""
    corpus = make_clustered_corpus(rng, n_docs=600, n_vocab=60)
    idx = build_index(corpus, 60, params=BM25Params())
    plain = DeviceRetriever(idx, regime="pruned", **SMALL)
    reord = DeviceRetriever(idx, regime="pruned", reorder="signature", **SMALL)

    def skip_rate(r):
        tot_p = tot_d = 0
        for seed in range(8):
            q = [np.array([seed % 2], np.int32),
                 np.random.default_rng(seed).integers(
                     0, 60, size=3).astype(np.int32)]
            r.retrieve_batch(q, 3)
            p = r.last_plan
            tot_p += p.frags_planned
            tot_d += p.frags_planned - p.frags_pruned - p.frags_skipped
        return (tot_p - tot_d) / max(tot_p, 1)

    assert skip_rate(reord) > skip_rate(plain)


# -- serve: donor adoption rules ----------------------------------------------

def test_reuse_requires_matching_permutation(rng):
    corpus = make_corpus(rng, n_docs=40, n_vocab=20)
    idx = build_index(corpus, 20, params=BM25Params())
    di_r = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                             reorder="signature")
    assert di_r.perm is not None and di_r.reorder == "signature"
    # same index, same reorder -> full adoption
    di2 = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                            reorder="signature", reuse_from=di_r)
    assert di2.reused == {"csc": True, "blocked": True, "bmax": True}
    np.testing.assert_array_equal(di2.perm, di_r.perm)
    # unordered build must NOT adopt a reordered donor's layouts
    di3 = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                            reuse_from=di_r)
    assert di3.reused == {"csc": False, "blocked": False, "bmax": False}
    assert di3.perm is None
    # and a reordered build must not adopt an unordered donor
    di_n = DeviceIndex.build(idx, block_size=16, tile=16, frag=8)
    di4 = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                            reorder="signature", reuse_from=di_n)
    assert di4.reused == {"csc": False, "blocked": False, "bmax": False}


def test_reordered_host_arrays_drop_serves_exactly(rng):
    corpus = make_clustered_corpus(rng, n_docs=120, n_vocab=40)
    idx = build_index(corpus, 40, params=BM25Params())
    keep = DeviceRetriever(idx, regime="pruned", reorder="signature", plan="device",
                           **SMALL)
    drop = DeviceRetriever(idx, regime="pruned", reorder="signature", plan="device",
                           host_arrays="drop", **SMALL)
    queries = [rng.integers(0, 40, size=4).astype(np.int32),
               np.array([0], np.int32)]
    i0, v0 = keep.retrieve_batch(queries, 5)
    i1, v1 = drop.retrieve_batch(queries, 5)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(v0, v1)


# -- engine: global ids stay client-space -------------------------------------

def test_engine_reordered_scorer_exact_and_ragged_rescale(rng):
    corpus = make_clustered_corpus(rng, n_docs=130, n_vocab=40)
    p = BM25Params(method="bm25+")
    shards = build_sharded_indexes(corpus, 40, 3, params=p)
    eng = RetrievalEngine(shards, k=5, deadline_s=30.0, scorer="pruned",
                          scorer_opts=dict(reorder="signature", **SMALL))
    qs = [np.array([0], np.int32),
          rng.integers(0, 40, size=4).astype(np.int32)]

    def check(eng):
        rb = eng.retrieve_batch(qs)
        assert not rb.degraded
        for i, q in enumerate(qs):
            oracle = dense_oracle_scores(corpus, 40, q, p)
            _, ref_v = topk_numpy(oracle[None], 5)
            np.testing.assert_allclose(rb.scores[i], ref_v[0], atol=1e-3)
            np.testing.assert_allclose(oracle[rb.ids[i]], rb.scores[i],
                                       atol=1e-3)

    check(eng)
    eng.rescale(4)          # 130 docs over 4 shards: ragged boundaries
    check(eng)
    eng.rescale(2)
    check(eng)


# -- hypothesis properties ----------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.data())
def test_property_permute_roundtrip(data):
    """Random corpora x variants: permuting with ANY valid permutation and
    un-permuting is bit-exact, and permuted scoring is a relabeling."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    n_vocab = data.draw(st.integers(3, 30))
    n_docs = data.draw(st.integers(2, 30))
    method = data.draw(st.sampled_from(ALL_VARIANTS))
    corpus = [rng.integers(0, n_vocab, size=rng.integers(0, 15)
                           ).astype(np.int32) for _ in range(n_docs)]
    idx = build_index(corpus, n_vocab, params=BM25Params(method=method))
    perm = rng.permutation(n_docs).astype(np.int32)
    idx_p = permute_index(idx, perm)
    back = unpermute_index(idx_p, perm)
    np.testing.assert_array_equal(back.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(back.scores, idx.scores)
    np.testing.assert_array_equal(back.doc_lens, idx.doc_lens)
    q = rng.integers(0, n_vocab, size=3).astype(np.int32)
    inv = invert_permutation(perm)
    np.testing.assert_array_equal(ScipyBM25(idx).score(q),
                                  ScipyBM25(idx_p).score(q)[inv])


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_property_reordered_serving_exact(data):
    """Random corpora x {variant, bound dtype, planner}: the reordered
    pruned path is bit-identical to its same-layout resident oracle, and
    true-score-correct vs scipy — including k >= n_docs and empty
    queries."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    n_vocab = data.draw(st.integers(8, 40))
    n_docs = data.draw(st.integers(6, 40))
    method = data.draw(st.sampled_from(ALL_VARIANTS))
    bmax_dtype = data.draw(st.sampled_from(["f32", "u8"]))
    plan = data.draw(st.sampled_from(["host", "device"]))
    corpus = [rng.integers(0, n_vocab, size=rng.integers(0, 20)
                           ).astype(np.int32) for _ in range(n_docs)]
    idx = build_index(corpus, n_vocab, params=BM25Params(method=method))
    oracle = _reordered_oracle(idx, bmax_dtype=bmax_dtype, plan=plan)
    pruned = DeviceRetriever(idx, regime="pruned", bmax_dtype=bmax_dtype, plan=plan,
                             reorder="signature", **SMALL)
    k = data.draw(st.sampled_from([1, 3, n_docs, n_docs + 5]))
    queries = [rng.integers(0, n_vocab, size=rng.integers(0, 5)
                            ).astype(np.int32) for _ in range(2)]
    queries.append(np.zeros(0, np.int32))
    i0, v0 = oracle.retrieve_batch(queries, k)
    i1, v1 = pruned.retrieve_batch(queries, k)
    np.testing.assert_array_equal(v0, v1)
    np.testing.assert_array_equal(i0, i1)
    sc = ScipyBM25(idx)
    kk = min(k, n_docs)
    for i, q in enumerate(queries):
        np.testing.assert_allclose(sc.score(q)[i1[i, :kk]], v1[i, :kk],
                                   atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_new=st.integers(1, 5))
def test_property_reordered_ragged_rescale(seed, n_new):
    """Rescaling to ragged shard sizes under reordered scorers keeps
    engine answers true to the dense oracle."""
    rng = np.random.default_rng(seed)
    corpus = [rng.integers(0, 30, size=rng.integers(0, 15)
                           ).astype(np.int32) for _ in range(41)]
    p = BM25Params(method="lucene")
    shards = build_sharded_indexes(corpus, 30, 3, params=p)
    eng = RetrievalEngine(shards, k=4, deadline_s=30.0, scorer="pruned",
                          scorer_opts=dict(reorder="signature", **SMALL),
                          warmup=False)
    eng.rescale(n_new)
    q = rng.integers(0, 30, size=3).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 30, q, p)
    _, ref_v = topk_numpy(oracle[None], 4)
    np.testing.assert_allclose(r.scores, ref_v[0], atol=1e-3)
    np.testing.assert_allclose(oracle[r.ids], r.scores, atol=1e-3)
