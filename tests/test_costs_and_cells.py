"""Cost-accounting correctness + every cell is constructible.

The roofline numbers are only as good as the loop-aware cost walker, so it
gets its own unit tests (exact scan trip counts, dot FLOPs from shapes).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.costs import collective_bytes_multiplied, traced_cost


def test_scan_flops_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = traced_cost(f, (x, w))
    matmul = 2 * 64 ** 3
    assert c["flops"] >= 10 * matmul                 # trip count applied
    assert c["flops"] < 10 * matmul * 1.5            # not wildly over


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = traced_cost(f, (x, w))
    assert c["flops"] >= 12 * 2 * 32 ** 3            # 3 x 4 trips


def test_dot_flops_from_contraction():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    c = traced_cost(f, (a, b))
    assert c["flops"] == pytest.approx(2 * 4 * 8 * 16 * 32, rel=0.01)


def test_grad_counts_backward_flops():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    fwd = traced_cost(loss, (w, x))["flops"]
    bwd = traced_cost(jax.grad(loss), (w, x))["flops"]
    assert bwd > 2 * fwd                             # fwd + 2 transposed dots


def test_collective_parser_multiplies_while_loops():
    hlo = """
HloModule test

%cond (p: (s32[])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%p.0, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %ag = f32[128,256] all-gather(%x), dimensions={0}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (a: f32[2]) -> f32[2] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ar = f32[64] all-reduce(%a), to_apply=%add
}
"""
    out = collective_bytes_multiplied(hlo)
    ag = 128 * 256 * 4
    assert out["per_op"]["all-gather"]["count"] == 7
    assert out["per_op"]["all-gather"]["wire_bytes"] == 7 * ag
    assert out["per_op"]["all-reduce"]["wire_bytes"] == 2 * 64 * 4


def test_all_cells_constructible():
    """Every registered cell builds abstract args on a 1-device mesh."""
    from repro.configs import all_cells
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh()
    cells = all_cells(include_extra=True)
    assert len(cells) == 41                     # 39 assigned + 2 bm25s
    archs = {c.arch for c in cells}
    assert len(archs) == 11
    # building the small cells fully is cheap; big LM cells: check lazily
    small = [c for c in cells if c.arch in ("egnn", "autoint", "sasrec")]
    for c in small:
        fn, args = c.build(mesh)
        assert callable(fn) and jax.tree.leaves(args)


def test_qwen_long500k_skipped():
    from repro.configs import get_cells
    shapes = {c.shape for c in get_cells("qwen3-8b")}
    assert "long_500k" not in shapes            # per assignment rule
    for arch in ("mixtral-8x7b", "gemma3-1b", "h2o-danube3-4b"):
        assert "long_500k" in {c.shape for c in get_cells(arch)}


def test_kv_quant_decode_numerics(rng):
    """int8 KV cache: rel error < 5%, greedy tokens unchanged (tiny LM)."""
    from dataclasses import replace
    from repro.models.transformer import (LMConfig, decode_step, forward,
                                          init_decode_cache, init_params)
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                   d_ff=64, vocab_size=97, head_dim=8, seq_chunk=8,
                   loss_chunk=8, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, 97, size=(2, 12)), jnp.int32)
    outs = {}
    for c in (cfg, replace(cfg, kv_quant=True)):
        cache = init_decode_cache(c, 2, 12)
        cache["pos"] = jnp.asarray(0, jnp.int32)
        for t in range(12):
            logits, cache = decode_step(c, params, cache, toks[:, t])
        outs[c.kv_quant] = np.asarray(logits)
    hidden, _ = forward(cfg, params, toks)
    ref = np.asarray(hidden[:, -1, :] @ params["lm_head"])
    assert np.abs(outs[True] - ref).max() / np.abs(ref).max() < 0.05
    assert (outs[True].argmax(-1) == ref.argmax(-1)).all()
