"""Deterministic, seedable fault injection for the serving stack.

The degradation ladder (``DeviceRetriever.retrieve_batch``) is only
trustworthy if every rung can actually be exercised; this module provides
the failure half of that contract. Injection points are registered INSIDE
the production code paths — ``sparse.block_csr.put_posting_arrays``,
``sparse.fragment_device.plan_fragments_device`` and the host wrapper of
``kernels.ops.bm25_retrieve_resident_pruned`` — but cost nothing when no
fault is armed: each site peeks at ``sys.modules`` for this module and
skips the hook entirely unless :data:`ACTIVE` is non-empty, so importing
the serving stack never pulls the harness in and the hot path pays one
dict lookup only while a fault is armed.

Fault sites and kinds
---------------------

=============================  ==========================================
site                           kinds
=============================  ==========================================
``residency.put_posting_arrays``  ``residency`` — the posting upload
                                  raises :class:`~.errors.ResidencyError`
                                  (simulated HBM pressure / failed DMA).
``plan.fragments_device``         ``overflow`` — the device fragment
                                  planner reports nf-bucket exhaustion as
                                  :class:`~.errors.PlanOverflowError`.
``kernel.resident_pruned``        ``nan_board`` / ``inf_board`` — the
                                  pruned kernel's ``[B, k]`` score board
                                  comes back with a NaN / Inf tile
                                  (caught by the retriever's cheap
                                  finite-check, surfaced as
                                  :class:`~.errors.ScoreIntegrityError`).
``query.batch``                   ``query.range`` / ``query.negative`` /
                                  ``query.dtype`` / ``query.ragged`` —
                                  the incoming batch is corrupted before
                                  validation (out-of-range ids, negative
                                  ids, dtype drift, None/ragged entries).
``snapshot.write``                ``torn_write`` — the snapshot writer is
                                  "killed" mid-write: the file just
                                  written is truncated on disk and the
                                  save raises ``OSError`` before the
                                  commit point (the previous snapshot
                                  generation must survive untouched).
``snapshot.manifest``             ``manifest_corrupt`` / ``stale_version``
                                  — the on-disk manifest is bit-flipped
                                  (checksum/parse failure) or rewritten
                                  with an unknown future format version
                                  (surfaced as ``SnapshotVersionError``).
``snapshot.array``                ``truncate`` / ``bit_flip`` — one array
                                  file is truncated or has a single bit
                                  flipped on disk; the loader's checksum
                                  pass must catch it and walk the
                                  snapshot recovery ladder.
``kernel.stall``                  ``stall`` — device execution of a hop
                                  hangs for a deterministic 150–250ms
                                  (simulated wedged launch). Under a
                                  retriever watchdog the stall surfaces
                                  as ``ExecutionStalledError`` and the
                                  ladder hops; without one it is only
                                  latency — recovery is exact either
                                  way (chaos-pool safe).
``frontend.former``               ``thread_death`` — an uncaught
                                  ``RuntimeError`` (an arbitrary bug,
                                  deliberately NOT a typed error) is
                                  raised inside the front-end's batch
                                  former loop; the stage supervisor
                                  must fail any in-flight requests
                                  typed and restart the stage.
``queue.flood``                   ``flood`` — the pending-queue depth
                                  the admission gate reads is inflated
                                  by a seeded burst (simulated arrival
                                  flood), forcing a typed shed
                                  (``AdmissionRejectedError`` /
                                  ``QueueOverflowError``). Fires only
                                  unguarded: shedding is designed
                                  behavior but changes what the caller
                                  gets, so it never joins a chaos pool.
=============================  ==========================================

The ``snapshot.*`` I/O lane mutates REAL files on disk (the paths the
loader is about to verify), so the whole save→crash→load→recover cycle is
probed end to end; corruption offsets are still pure functions of
``(seed, fire_count)``.

Every mutation is a pure function of ``(seed, fire_count)`` — re-running
the same test with the same spec replays the same corruption, byte for
byte. Specs are **guarded** by default: they fire only inside a
retriever's ladder scope (:func:`guard`), so arming a fault globally (the
``--chaos`` pytest mode) cannot crash code that has no recovery path —
index construction at session setup, warmup's forced-regime calls, and
strict (``on_fault="raise"`` or per-call ``regime=``) retrievals all stay
outside the guard. Pass ``guarded=False`` to hit a site wherever it is
called (required when testing strict-mode surfacing).

Example
-------

>>> import numpy as np
>>> from repro.core import BM25Params, build_index
>>> from repro.serve import DeviceRetriever
>>> from repro.serve.faults import inject_faults
>>> rng = np.random.default_rng(0)
>>> corpus = [rng.integers(0, 32, size=8).astype(np.int32)
...           for _ in range(40)]
>>> idx = build_index(corpus, 32, params=BM25Params(method="lucene"))
>>> dr = DeviceRetriever(idx, regime="gathered", gather="host",
...                      block_size=16, tile=16, acc_block=16, q_max=8)
>>> q = [np.array([1, 2, 3], dtype=np.int32)]
>>> ids0, vals0 = dr.retrieve_batch(q, 5)          # healthy run
>>> with inject_faults({"site": "residency.put_posting_arrays",
...                     "kind": "residency", "times": 1, "seed": 7}):
...     ids1, vals1 = dr.retrieve_batch(q, 5)      # upload fails once
>>> bool(np.allclose(vals0, vals1, atol=1e-5))     # ladder recovered,
True
>>> dr.last_plan.degradations[0]["to"]             # via the oracle hop
'oracle'

How to add an injection point
-----------------------------

1. Pick a site name (``"<layer>.<function>"``) and add it to
   :data:`SITES` with its fault kinds.
2. At the production call site, peek-and-fire (import-free on the
   healthy path)::

       import sys
       _f = sys.modules.get("repro.serve.faults")
       if _f is not None and _f.ACTIVE:
           payload = _f.fire("my.site", payload, extra_ctx=...)

   ``fire`` either raises the typed error for the armed kind or returns
   the (possibly corrupted) payload.
3. Give the fault a recovery rung in the ladder (or document that strict
   mode is the only option) and cover it in ``tests/test_faults.py``.
"""

from __future__ import annotations

import contextlib
import sys
import threading
from dataclasses import dataclass, field

import numpy as np

from .errors import PlanOverflowError, ResidencyError

SITES: dict[str, tuple[str, ...]] = {
    "residency.put_posting_arrays": ("residency",),
    "plan.fragments_device": ("overflow",),
    "kernel.resident_pruned": ("nan_board", "inf_board"),
    "query.batch": ("query.range", "query.negative", "query.dtype",
                    "query.ragged"),
    "snapshot.write": ("torn_write",),
    "snapshot.manifest": ("manifest_corrupt", "stale_version"),
    "snapshot.array": ("truncate", "bit_flip"),
    "kernel.stall": ("stall",),
    "frontend.former": ("thread_death",),
    "queue.flood": ("flood",),
}


@dataclass
class FaultSpec:
    """One armed fault: where, what, how often, and its deterministic seed."""

    site: str
    kind: str
    times: int = 1              # max firings while armed (bounded chaos)
    seed: int = 0               # corruption PRNG seed (mutating kinds)
    guarded: bool = True        # fire only inside a ladder guard() scope
    fired: int = field(default=0, compare=False)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"available: {sorted(SITES)}")
        if self.kind not in SITES[self.site]:
            raise ValueError(f"site {self.site!r} has no kind "
                             f"{self.kind!r}; available: {SITES[self.site]}")


ACTIVE: list[FaultSpec] = []          # armed specs (inject_faults scope)
FIRED: dict[str, int] = {}            # site -> total fires (observability)

_tls = threading.local()


@contextlib.contextmanager
def guard():
    """Mark a ladder scope: guarded specs fire only inside this context."""
    depth = getattr(_tls, "depth", 0)
    _tls.depth = depth + 1
    try:
        yield
    finally:
        _tls.depth = depth


def in_guard() -> bool:
    return getattr(_tls, "depth", 0) > 0


def _normalize(spec) -> list[FaultSpec]:
    if isinstance(spec, FaultSpec):
        return [spec]
    if isinstance(spec, dict):
        return [FaultSpec(**spec)]
    return [s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in spec]


@contextlib.contextmanager
def inject_faults(spec):
    """Arm one or more faults for the duration of the ``with`` block.

    ``spec`` is a :class:`FaultSpec`, a dict of its fields, or a list of
    either. Yields the list of armed specs (inspect ``spec.fired`` after
    the block to see how many times each actually hit). See the module
    docstring for a runnable end-to-end example.
    """
    specs = _normalize(spec)
    ACTIVE.extend(specs)
    try:
        yield specs
    finally:
        for s in specs:
            ACTIVE.remove(s)


def _match(site: str) -> FaultSpec | None:
    for s in ACTIVE:
        if s.site == site and s.fired < s.times and (not s.guarded
                                                     or in_guard()):
            return s
    return None


def _corrupt_board(vals, kind: str, rng: np.random.Generator):
    """Poison one entry of the [B, k] score board (NaN or +Inf).

    Always hits row 0: the batch dimension is pow2-padded and padding
    rows are sliced off before the finite-check, so a poisoned padding
    row would be an injected fault nobody can observe. Row 0 is real in
    every non-empty batch.
    """
    import jax.numpy as jnp
    arr = np.array(vals, dtype=np.float32, copy=True)
    if arr.size == 0:
        return vals
    col = int(rng.integers(0, arr.shape[-1]))
    arr[(0,) * (arr.ndim - 1) + (col,)] = (np.nan if kind == "nan_board"
                                           else np.inf)
    return jnp.asarray(arr)


def _corrupt_queries(queries, kind: str, rng: np.random.Generator,
                     n_vocab: int):
    """Return a corrupted copy of the client batch (payload untouched)."""
    out = [np.array(q, copy=True) if q is not None else None
           for q in queries]
    live = [i for i, q in enumerate(out)
            if q is not None and np.asarray(q).size]
    if not live:
        return out
    i = int(live[rng.integers(0, len(live))])
    q = np.asarray(out[i])
    j = int(rng.integers(0, q.size))
    if kind == "query.range":
        q = q.astype(np.int64, copy=True)
        q.flat[j] = n_vocab + int(rng.integers(1, 100))
        out[i] = q
    elif kind == "query.negative":
        q = q.astype(np.int64, copy=True)
        q.flat[j] = -1 - int(rng.integers(0, 100))
        out[i] = q
    elif kind == "query.dtype":
        out[i] = q.astype(np.float64)          # integral drift: recastable
    elif kind == "query.ragged":
        out[i] = None                          # dropped-by-client entry
        if len(live) > 1:
            i2 = int(live[(live.index(i) + 1) % len(live)])
            out[i2] = np.asarray(out[i2]).reshape(1, -1)   # 2-D drift
    return out


def _corrupt_snapshot_file(path, kind: str, rng: np.random.Generator):
    """Mutate a snapshot file on disk; pure function of the rng state.

    ``path`` may be a list of candidate files (the payload the snapshot
    loader/writer passes) — one is chosen by the rng, so which file a
    chaos run corrupts varies with the seed while staying replayable.
    ``torn_write`` / ``truncate`` chop the file to a strict prefix (at
    least one byte short); ``bit_flip`` flips one bit at an rng-chosen
    offset; ``manifest_corrupt`` is a bit flip too (a torn or flipped
    manifest both surface as parse/checksum failures);
    ``stale_version`` rewrites the manifest with an unknown future
    version and a RECOMPUTED manifest checksum, so the version check —
    not the checksum — is what trips.
    """
    import os
    if isinstance(path, (list, tuple)):
        path = path[int(rng.integers(0, len(path)))]
    path = str(path)
    size = os.path.getsize(path)
    if kind == "stale_version":
        import json
        from ..sparse import snapshot as _snap
        with open(path, encoding="utf-8") as fh:
            manifest = json.load(fh)
        manifest["version"] = int(manifest.get("version", 0)) + 999
        manifest.pop("manifest_checksum", None)
        manifest["manifest_checksum"] = _snap.manifest_checksum(manifest)
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(manifest, fh)
        return
    if size == 0:
        return
    if kind in ("torn_write", "truncate"):
        keep = int(rng.integers(0, size))      # strict prefix: 0..size-1
        os.truncate(path, keep)
        return
    # bit_flip / manifest_corrupt: flip one bit in place
    off = int(rng.integers(0, size))
    bit = int(rng.integers(0, 8))
    with open(path, "r+b") as fh:
        fh.seek(off)
        byte = fh.read(1)
        fh.seek(off)
        fh.write(bytes([byte[0] ^ (1 << bit)]))


def fire(site: str, payload=None, *, n_vocab: int | None = None):
    """Hook called by instrumented sites. Raises or transforms ``payload``.

    Returns ``payload`` (possibly a corrupted copy) when no raising fault
    is armed for ``site``. Deterministic: the corruption PRNG is seeded
    from ``(spec.seed, spec.fired)``.
    """
    spec = _match(site)
    if spec is None:
        return payload
    spec.fired += 1
    FIRED[site] = FIRED.get(site, 0) + 1
    rng = np.random.default_rng((spec.seed, spec.fired))
    if spec.kind == "residency":
        raise ResidencyError(
            f"injected: posting-array upload failed at {site} "
            f"(spec seed={spec.seed}, fire #{spec.fired})")
    if spec.kind == "overflow":
        raise PlanOverflowError(
            f"injected: nf-bucket regrowth exhausted at {site} "
            f"(spec seed={spec.seed}, fire #{spec.fired})",
            attempted=[8, 16, 32], cap=32)
    if spec.kind in ("nan_board", "inf_board"):
        return _corrupt_board(payload, spec.kind, rng)
    if spec.kind.startswith("query."):
        return _corrupt_queries(payload, spec.kind, rng,
                                n_vocab=int(n_vocab or 0) or (1 << 30))
    if site.startswith("snapshot."):
        _corrupt_snapshot_file(payload, spec.kind, rng)
        if spec.kind == "torn_write":
            raise OSError(
                f"injected: process killed mid-write at {site} "
                f"({payload}; spec seed={spec.seed}, fire #{spec.fired})")
        return payload
    if spec.kind == "stall":
        # a wedged device launch: block the calling (worker) thread for a
        # deterministic 150-250ms — far past any test watchdog, bounded
        # enough that an unguarded retriever merely slows down (exact
        # recovery either way, which is what makes it chaos-pool safe)
        import time as _time
        _time.sleep(0.15 + 0.1 * float(rng.random()))
        return payload
    if spec.kind == "thread_death":
        # deliberately NOT a RetrievalError: simulates an arbitrary bug
        # escaping the former loop, which only the stage supervisor
        # (not the typed ladder) can absorb
        raise RuntimeError(
            f"injected: former thread death at {site} "
            f"(spec seed={spec.seed}, fire #{spec.fired})")
    if spec.kind == "flood":
        # inflate the queue depth the admission gate is about to read —
        # a simulated arrival burst, sized by the spec's seeded rng
        return int(payload or 0) + 10_000 + int(rng.integers(0, 1000))
    raise AssertionError(f"unhandled fault kind {spec.kind!r}")


def peek():
    """The module handle instrumented sites use, or None when not loaded.

    Convenience mirror of the inline ``sys.modules.get`` idiom (useful in
    tests asserting the zero-cost property).
    """
    return sys.modules.get(__name__)


__all__ = ["SITES", "FaultSpec", "ACTIVE", "FIRED", "inject_faults",
           "fire", "guard", "in_guard", "peek"]
