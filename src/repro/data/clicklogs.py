"""Synthetic recsys data: CTR click logs and sequential behaviour.

Labels come from a planted logistic/affinity model so the training tests
can assert that loss decreases toward the (known) achievable level.
"""

from __future__ import annotations

import numpy as np


def ctr_batches(*, vocab_sizes, n_dense: int, batch: int, seed: int = 0):
    """DLRM/AutoInt batches with a planted logistic CTR model."""
    rng = np.random.default_rng(seed)
    n_fields = len(vocab_sizes)
    field_w = [rng.normal(scale=0.5, size=v) for v in vocab_sizes]
    dense_w = rng.normal(scale=0.5, size=n_dense) if n_dense else None
    while True:
        sparse = np.stack(
            [rng.integers(0, v, size=batch) for v in vocab_sizes],
            axis=1).astype(np.int32)
        logit = sum(field_w[f][sparse[:, f]] for f in range(n_fields))
        out = {"sparse": sparse}
        if n_dense:
            dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
            logit = logit + dense @ dense_w
            out["dense"] = dense
        p = 1.0 / (1.0 + np.exp(-logit))
        out["labels"] = (rng.random(batch) < p).astype(np.int32)
        yield out


def seq_rec_batches(*, n_items: int, seq_len: int, batch: int, seed: int = 0,
                    per_position: bool = True):
    """SASRec/MIND batches: histories walk item clusters; positives stay
    in-cluster, negatives are uniform."""
    rng = np.random.default_rng(seed)
    n_clusters = 32
    cluster_of = rng.integers(0, n_clusters, size=n_items + 1)
    items_of = [np.where(cluster_of == c)[0] for c in range(n_clusters)]
    items_of = [c[c > 0] if (c > 0).any() else np.array([1]) for c in items_of]
    while True:
        hist = np.zeros((batch, seq_len), np.int32)
        c = rng.integers(0, n_clusters, size=batch)
        for t in range(seq_len):
            jump = rng.random(batch) < 0.05
            c = np.where(jump, rng.integers(0, n_clusters, size=batch), c)
            hist[:, t] = [int(rng.choice(items_of[ci])) for ci in c]
        if per_position:
            pos = np.roll(hist, -1, axis=1)
            pos[:, -1] = [int(rng.choice(items_of[ci])) for ci in c]
            neg = rng.integers(1, n_items, size=(batch, seq_len)).astype(np.int32)
        else:
            pos = np.array([int(rng.choice(items_of[ci])) for ci in c],
                           dtype=np.int32)
            neg = rng.integers(1, n_items, size=batch).astype(np.int32)
        yield {"history": hist, "pos_items": pos.astype(np.int32),
               "neg_items": neg}
