"""Build-time doc-id reordering: cluster documents by posting signature.

Block-max pruning (``block_csr.BlockMaxTable``) is only as strong as its
blocks are homogeneous: with arbitrary doc order a block's per-token upper
bound is set by its single hottest document, so the summed query-side bound
``Σ_t w_t · bmax[t, b]`` stays loose and the pruned regime still DMAs a
large fraction of the planned fragments. The classic BMW companion trick is
to RE-NUMBER documents so that docs with similar posting signatures share
blocks — per-block maxima drop, bounds tighten, skip rates rise — without
touching exactness, because winner ids are remapped back to client ids at
the merge (a single host-side gather on the ``[B, k]`` board).

Two signature schemes are provided; ``benchmarks/reorder.py`` microbenches
both and BENCH_6.json records why the default is the **top-weight token
sort**:

* ``"signature"`` (default) — each document's signature is its
  ``SIGNATURE_WIDTH`` highest-weight tokens (by the eagerly-scored posting
  weight, the exact quantity the block-max table bounds). A stable lexsort
  over the signature columns clusters docs sharing dominant tokens into
  runs, i.e. into the same 64-doc blocks. O(nnz) signature extraction +
  one O(n_docs·width) sort; on BENCH_1-scale corpora this costs ~2-4% of
  indexing throughput and wins the largest skip-rate gain because it
  concentrates exactly the per-token maxima the bounds sum over.
* ``"minhash"`` — classic Jaccard-similarity clustering: per-doc min-wise
  hashes of the token SET under ``MINHASH_WIDTH`` universal hash
  functions, lexsorted. Cheaper per doc than a content sort for huge
  vocabularies, but weight-blind: it groups docs sharing ANY tokens, not
  docs sharing HOT tokens, so its bounds stay looser (see BENCH_6's
  microbench block — it trails the signature sort at the same cost).

Both permutations are DETERMINISTIC functions of the index (ties broken by
original doc id via stable sorts). That determinism is a recovery rung:
a snapshot whose ``perm`` array (and its ``.dup`` replica) is corrupt can
recompute the permutation from the stored client-order postings and verify
it against the manifest checksum (see ``sparse.snapshot``).

The permutation convention throughout the stack is ``perm: new_id ->
old_id`` — ``perm[i]`` is the client id of the doc serving as device-side
doc ``i``. The inverse (``old -> new``) relabels postings at build time.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

# top-weight tokens per signature; 4 keys cluster on the Zipf head that
# dominates block bounds while keeping the lexsort cheap
SIGNATURE_WIDTH = 4
MINHASH_WIDTH = 4
# deterministic odd multipliers for the universal minhash family
# (splitmix64-style mixing constants)
_MINHASH_MULT = (0x9E3779B97F4A7C15, 0xBF58476D1CE4E5B9,
                 0x94D049BB133111EB, 0xD6E8FEB86659FD93)

REORDER_MODES = ("none", "signature", "minhash")


def _coo_tok(index) -> np.ndarray:
    """Token id per posting, expanded from the CSC run-descriptor table."""
    return np.repeat(np.arange(index.n_vocab, dtype=np.int64),
                     np.diff(index.indptr))


def _sortable_score_key(scores) -> np.ndarray:
    """Map f32 weights to uint32-range keys with the same total order.

    Standard IEEE-754 trick: flip the sign bit for non-negative floats,
    complement negative ones. Lets the weight-descending selection below
    run on integer keys instead of a float lexsort (~3x faster).
    """
    bits = np.ascontiguousarray(scores).view(np.uint32).astype(np.uint64)
    return np.where(bits >= 0x80000000, ~bits & np.uint64(0xFFFFFFFF),
                    bits | np.uint64(0x80000000))


def doc_signatures(index, *, width: int = SIGNATURE_WIDTH) -> np.ndarray:
    """Per-doc top-weight token signature, ``[n_docs, width]`` int64.

    Row ``d`` holds doc ``d``'s ``width`` highest-weight tokens in
    descending stored-weight order (ties by ascending token id), padded
    with the sentinel ``n_vocab`` for docs with fewer postings.

    Sort-free extraction: one C-level CSC->CSR counting transpose groups
    postings doc-major, then ``width`` rounds of segmented max
    (``np.maximum.reduceat`` on composite ``weight_key << 32 | ~token``
    values, zeroing each round's winner) peel off the top tokens —
    O(width * nnz) with no comparison sort over the posting stream. Falls
    back to a stable composite argsort when scipy is unavailable; both
    paths produce identical signatures (tested in tests/test_reorder.py).
    """
    n_docs = int(index.doc_lens.size)
    sig = np.full((n_docs, width), int(index.n_vocab), dtype=np.int64)
    nnz = int(index.doc_ids.size)
    if nnz == 0:
        return sig
    skey = _sortable_score_key(index.scores)
    try:
        import scipy.sparse as sp
    except ImportError:
        sp = None
    if sp is not None:
        # skey + 1 keeps every explicit entry strictly above scipy's
        # implicit zeros so exhausted rows read back as the sentinel
        m = sp.csc_matrix((skey + np.uint64(1), index.doc_ids,
                           index.indptr),
                          shape=(n_docs, int(index.n_vocab))).tocsr()
        rs = m.indptr
        comp = ((m.data << np.uint64(32))
                | (np.uint64(0xFFFFFFFF) - m.indices.astype(np.uint64)))
        row_of = np.repeat(np.arange(n_docs, dtype=np.int64), np.diff(rs))
        nonempty = rs[:-1] < rs[1:]
        starts = rs[:-1][nonempty]
        rows_ne = np.flatnonzero(nonempty)
        for r in range(width):
            mx = np.maximum.reduceat(comp, starts)
            ok = mx > 0
            sig[rows_ne[ok], r] = (np.uint64(0xFFFFFFFF)
                                   - (mx[ok] & np.uint64(0xFFFFFFFF))
                                   ).astype(np.int64)
            if r == width - 1:
                break
            # retire each row's winner (first — lowest-token — match)
            mxe = np.zeros(n_docs, dtype=np.uint64)
            mxe[rows_ne] = mx
            match = np.flatnonzero(comp == mxe[row_of])
            first = match[np.unique(row_of[match], return_index=True)[1]]
            comp[first] = 0
        return sig
    # numpy-only fallback: composite stable sort doc-major / weight-desc
    # (stability keeps token-ascending order inside weight ties, matching
    # the reduceat path's first-match rule), then scatter within-doc rank
    tok = _coo_tok(index)
    doc = index.doc_ids.astype(np.int64)
    key = ((doc.astype(np.uint64) << np.uint64(32))
           | (np.uint64(0xFFFFFFFF) - skey))
    order = np.argsort(key, kind="stable")
    d_s, t_s = doc[order], tok[order]
    starts = np.zeros(n_docs + 1, dtype=np.int64)
    starts[1:] = np.bincount(d_s, minlength=n_docs)
    np.cumsum(starts, out=starts)
    rank = np.arange(nnz, dtype=np.int64) - starts[d_s]
    keep = rank < width
    sig[d_s[keep], rank[keep]] = t_s[keep]
    return sig


def minhash_signatures(index, *, width: int = MINHASH_WIDTH) -> np.ndarray:
    """Per-doc min-wise token-set hashes, ``[n_docs, width]`` uint64."""
    n_docs = int(index.doc_lens.size)
    sig = np.full((n_docs, width), np.iinfo(np.uint64).max, dtype=np.uint64)
    nnz = int(index.doc_ids.size)
    if nnz == 0:
        return sig
    tok = _coo_tok(index).astype(np.uint64)
    doc = index.doc_ids.astype(np.int64)
    for i in range(width):
        with np.errstate(over="ignore"):
            # additive pre-mix before the multiply so token 0 (the Zipf
            # head, present in nearly every doc) doesn't hash to 0 under
            # every function and collapse all signatures
            h = ((tok + np.uint64(_MINHASH_MULT[(i + 1)
                                                % len(_MINHASH_MULT)]))
                 * np.uint64(_MINHASH_MULT[i % len(_MINHASH_MULT)]))
            h ^= h >> np.uint64(31)
        np.minimum.at(sig[:, i], doc, h)
    return sig


def signature_permutation(index, *, mode: str = "signature"
                          ) -> np.ndarray | None:
    """``perm: new_id -> old_id`` clustering docs by posting signature.

    Returns None when the permutation degenerates to the identity (tiny
    or empty shards, or an already-clustered order) — callers treat None
    as "no reorder", keeping every fast path untouched.
    """
    if mode not in REORDER_MODES:
        raise ValueError(f"unknown reorder mode {mode!r}; "
                         f"expected one of {REORDER_MODES}")
    n_docs = int(index.doc_lens.size)
    if mode == "none" or n_docs <= 1:
        return None
    sig = (doc_signatures(index) if mode == "signature"
           else minhash_signatures(index))
    # lexsort: last key is primary -> column 0 (the hottest token) leads;
    # stable, so full-signature ties keep ascending client-id order and
    # the permutation is a pure deterministic function of the index
    perm = np.lexsort(tuple(sig[:, c] for c in range(sig.shape[1] - 1,
                                                     -1, -1)))
    perm = perm.astype(np.int32)
    if np.array_equal(perm, np.arange(n_docs, dtype=np.int32)):
        return None
    return perm


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    """``inv[old_id] = new_id`` for a ``perm: new_id -> old_id``."""
    inv = np.empty(perm.size, dtype=np.int32)
    inv[perm] = np.arange(perm.size, dtype=np.int32)
    return inv


def is_permutation(perm, n_docs: int) -> bool:
    """Cheap structural validation (snapshot loads run this on untrusted
    bytes when checksum verification is off)."""
    p = np.asarray(perm)
    if p.ndim != 1 or p.size != n_docs:
        return False
    if p.size == 0:
        return True
    if p.min() < 0 or p.max() >= n_docs:
        return False
    return bool(np.unique(p).size == n_docs)


def permute_index(index, perm: np.ndarray):
    """Relabel an index's documents by ``perm`` (new_id -> old_id).

    One stable lexsort restores the CSC invariant (doc ids ascending
    within each token run) in the new id space; scores travel with their
    postings untouched, so every document's score vector is bit-identical
    — only its id changes. ``indptr``/``nonoccurrence`` are per-token and
    permutation-invariant.
    """
    inv = invert_permutation(perm)
    nnz = int(index.doc_ids.size)
    if nnz == 0:
        return replace(index, doc_lens=np.asarray(index.doc_lens)[perm])
    tok = _coo_tok(index)
    new_doc = inv[index.doc_ids].astype(np.int64)
    # (tok, new_doc) pairs are unique, so a single composite-int64 key
    # needs no stability and an unstable argsort is ~6x the lexsort speed
    order = np.argsort(tok * np.int64(index.doc_lens.size) + new_doc)
    return replace(
        index,
        doc_ids=new_doc[order].astype(np.int32),
        scores=np.asarray(index.scores)[order],
        doc_lens=np.asarray(index.doc_lens)[perm],
    )


def unpermute_index(index_p, perm: np.ndarray):
    """Exact inverse of :func:`permute_index` (client order back)."""
    return permute_index(index_p, invert_permutation(perm))


def permutations_equal(a, b) -> bool:
    """Donor-compatibility check: identical reorder (both None, or
    element-equal arrays). A reordered index must never adopt an
    unordered donor's resident layouts — and vice versa."""
    if a is None or b is None:
        return a is None and b is None
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


def remap_board(ids: np.ndarray, board: np.ndarray,
                perm: np.ndarray) -> np.ndarray:
    """Winner-id remap at the merge: device-local ids -> client ids.

    A single host-side gather on the ``[B, k]`` id board — zero extra
    device bytes (TRANSFERS-asserted in tier-1). Rows are then re-sorted
    by ``(-score, client_id)``: scores are already descending, so this is
    the identity everywhere except inside bit-equal score ties, where it
    pins a deterministic ascending-client-id order independent of the
    permutation that produced the board.
    """
    out = perm.astype(np.int64, copy=False)[ids]
    if out.size == 0:
        return out
    order = np.lexsort((out, -board.astype(np.float64, copy=False)),
                       axis=-1)
    reordered = np.take_along_axis(out, order, axis=-1)
    # scores within a tie run are bit-equal, so the board itself is
    # unchanged by construction — only ids move
    return reordered


__all__ = [
    "REORDER_MODES", "SIGNATURE_WIDTH", "MINHASH_WIDTH",
    "doc_signatures", "minhash_signatures", "signature_permutation",
    "invert_permutation", "is_permutation", "permute_index",
    "unpermute_index", "permutations_equal", "remap_board",
]
