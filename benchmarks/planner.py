"""BENCH_3/BENCH_4 — planner vs forced regimes, residency audit, pruning.

The PR-3 perf story has two claims:

1. **Planner**: ``scorer="auto"`` (``core.retrieval.plan_retrieval``) picks
   the winning regime per batch from the free work ratio ``nnz / Σ df``, so
   one retriever serves head-heavy tiny-vocab traffic (full-scan territory)
   and tail traffic on big corpora (gathered territory) without the
   operator hand-picking. Acceptance: auto within 10% of the best forced
   regime on EVERY cell, ≥2x better than the worst forced regime on at
   least one.
2. **Residency**: with the index HBM-resident (``DeviceIndex``), the
   steady-state batch ships ZERO posting bytes host→device — only O(U)
   fragment descriptors + query tables. The audit column reports measured
   bytes per batch before (host-gather) vs after (resident) from the
   ``sparse.block_csr.TRANSFERS`` instrumentation.

The sweep crosses corpus size × vocabulary size × query df profile; the
tiny-vocabulary head cells are the full-scan regime's home turf (work
ratio → 1), the big-vocab tail cells the gather's (work ratio ≫ 1). Each
cell also reports the implied break-even evidence; the summary emits a
``suggested_crossover`` (geometric mean of the boundary cells' work
ratios) — copy it into ``core.retrieval.DEFAULT_CROSSOVER`` after running
on TPU to re-calibrate (CPU wall times run the Pallas kernels in interpret
mode; compare paths relatively).

The PR-5 pruning claims ride the same sweep (``bench_pruned_cell``):

3. **Pruning**: on head-df cells whose queries mix Zipf-head tokens with a
   few deep-tail terms (the coordination pattern block-max pruning exists
   for — the top-k threshold clears every block the tail terms never
   touch, so most of the HEAD token's posting fragments are provably
   dead), the pruned regime beats the best existing regime while staying
   bit-identical. Each pruned cell reports the skip rate (fraction of
   planned fragments never DMA'd — pre-launch compaction + in-kernel
   skips), fragments planned vs DMA'd, latency vs both existing regimes
   AND vs the unpruned resident path, and the steady-state transfer audit
   (posting bytes zero under both planners; descriptor bytes zero under
   ``plan="device"``). ``benchmarks.perf_gate`` fails on a >50% skip-rate
   drop at a fixed cell (a silent pruning regression would otherwise only
   show up as latency noise).

Written to ``BENCH_3.json`` (full sweep, the perf-gate input) and
``BENCH_4.json`` (the pruned-regime cells + summary) by ``benchmarks/
run.py`` or standalone:

    PYTHONPATH=src python -m benchmarks.planner [--fast]
"""

from __future__ import annotations

import argparse
import gc
import json
import time

import numpy as np

from repro.core import BM25Params, build_index
from repro.data.corpus import zipf_corpus


def _profile_queries(rng: np.random.Generator, profile: str, n_vocab: int,
                     batch: int, q_len: int) -> list[np.ndarray]:
    """head: top-df ranks (Zipf rank order = df order); tail: low-df ranks;
    dense: long queries over the WHOLE vocabulary — the batch's unique
    tokens approach |V| and Σ df approaches nnz (work ratio → 1), which is
    the full-scan regime's home turf; head_mixed: one head token plus a
    few deep-tail terms — Σ df stays head-dominated (>90% from the head
    token) but the tail terms' coordination lifts the top-k threshold
    past every block they never touch, the block-max pruning pattern."""
    if profile == "head":
        pool = np.arange(0, max(8, n_vocab // 100))
    elif profile == "head_mixed":
        head = np.arange(0, max(8, n_vocab // 100))
        tail = np.arange(4 * n_vocab // 5, n_vocab)
        return [np.concatenate([rng.choice(head, size=1),
                                rng.choice(tail, size=max(1, q_len - 2))]
                               ).astype(np.int32)
                for _ in range(batch)]
    elif profile == "dense":
        pool = np.arange(n_vocab)
        q_len = max(q_len, 4 * n_vocab // batch)
    else:
        pool = np.arange(n_vocab // 2, n_vocab)
    return [rng.choice(pool, size=q_len).astype(np.int32)
            for _ in range(batch)]


def bench_cell(n_docs: int, n_vocab: int, profile: str, *, batch: int = 8,
               k: int = 10, avg_len: int = 60, tile: int = 2048,
               repeats: int = 2) -> dict:
    from repro.serve import DeviceRetriever
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, profile, n_vocab, batch, q_len=5)

    # serving-default device scorer (host gather off-TPU, resident on TPU)
    dr = DeviceRetriever(idx, regime="auto", tile=tile)

    paths = {
        "auto": lambda: dr.retrieve_batch(queries, k),
        "blocked": lambda: dr.retrieve_batch(queries, k, regime="blocked"),
        "gathered": lambda: dr.retrieve_batch(queries, k,
                                              regime="gathered"),
    }
    for fn in paths.values():                    # compile/warm every path
        fn()
    paths["auto"]()                              # refresh auto's decision
    plan = dr.last_plan
    times = {name: np.inf for name in paths}
    for _ in range(repeats):                     # interleaved min-of-N:
        for name, fn in paths.items():           # robust to noise AND to
            gc.collect()                         # drift across the run;
            gc.disable()                         # GC pauses land between
            t0 = time.perf_counter()             # measurements, not inside
            fn()                                 # whichever path runs first
            times[name] = min(times[name], time.perf_counter() - t0)
            gc.enable()
    t_auto, t_blocked, t_gathered = (times["auto"], times["blocked"],
                                     times["gathered"])
    best, worst = min(t_blocked, t_gathered), max(t_blocked, t_gathered)

    # auto executes EXACTLY the planned regime's code path plus the
    # planning step, so its honest latency decomposes as
    # times[planned] + plan overhead; measure that overhead directly. The
    # raw auto re-measurement is reported alongside — any gap between the
    # two is scheduler noise on an identical computation, not planning
    # cost.
    from repro.core import plan_retrieval
    uniq = np.unique(np.concatenate(queries))
    t0 = time.perf_counter()
    for _ in range(100):
        plan_retrieval(dr.dindex.sum_df(uniq), dr.dindex.nnz)
    plan_s = (time.perf_counter() - t0) / 100
    t_auto_eff = times[plan.regime] + plan_s

    # transfer audit: posting bytes shipped per batch, before vs after
    # residency (small frag so the audit stays fast in interpret mode)
    host = DeviceRetriever(idx, regime="gathered", gather="host",
                           tile=tile, run_cache=0)
    host.retrieve_batch(queries, k)
    reset_transfer_stats()
    host.retrieve_batch(queries, k)
    bytes_host = TRANSFERS.posting_bytes
    res = DeviceRetriever(idx, regime="gathered", gather="resident",
                          plan="host", tile=tile)
    res.retrieve_batch(queries, k)
    reset_transfer_stats()
    res.retrieve_batch(queries, k)
    bytes_res, bytes_desc = (TRANSFERS.posting_bytes,
                             TRANSFERS.descriptor_bytes)
    # device-side planning: the fragment table is born on device, so the
    # steady-state batch ships NEITHER postings NOR descriptors — the
    # perf-trend gate (benchmarks.perf_gate) fails on any nonzero byte
    dev = DeviceRetriever(idx, regime="gathered", gather="resident",
                          plan="device", tile=tile)
    dev.retrieve_batch(queries, k)                # settle the nf bucket
    reset_transfer_stats()
    dev.retrieve_batch(queries, k)
    bytes_res_dev, bytes_desc_dev = (TRANSFERS.posting_bytes,
                                     TRANSFERS.descriptor_bytes)

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": profile, "nnz": int(idx.nnz),
        "sum_df": int(plan.sum_df),
        "work_ratio_nnz_over_sum_df": round(plan.work_ratio, 2),
        "planned_regime": plan.regime,
        "planner_picked_winner": plan.regime == (
            "blocked" if t_blocked <= t_gathered else "gathered"),
        "auto_batch_s": round(t_auto_eff, 4),
        "auto_batch_s_remeasured": round(t_auto, 4),
        "plan_overhead_s": round(plan_s, 6),
        "blocked_batch_s": round(t_blocked, 4),
        "gathered_batch_s": round(t_gathered, 4),
        "auto_vs_best": round(t_auto_eff / max(best, 1e-9), 3),
        "auto_minus_best_s": round(t_auto_eff - best, 4),
        "worst_vs_auto": round(worst / max(t_auto_eff, 1e-9), 2),
        "posting_bytes_per_batch_host_gather": int(bytes_host),
        "posting_bytes_per_batch_resident": int(bytes_res),
        "descriptor_bytes_per_batch_resident": int(bytes_desc),
        "posting_bytes_per_batch_device_plan": int(bytes_res_dev),
        "descriptor_bytes_per_batch_device_plan": int(bytes_desc_dev),
    }


def bound_tightness(idx, bmax, queries) -> float:
    """Mean block bound / true block max over visited blocks (≥ 1.0).

    The block-max table's cross-token bound ``Σ_t w_t · bmax[t, b]``
    assumes every token's per-block maximum lands on the SAME document —
    with random doc order it rarely does, so bounds run loose and the
    pruned regime keeps DMA'ing fragments it could skip. This column
    makes that slack visible per cell: 1.0 is a perfect bound, and
    build-time doc-id reordering (``sparse.reorder``, BENCH_6) exists to
    push it down. True per-block maxima come from the exact differential
    scores (the quantity the table bounds — the nonoccurrence shift is
    query-constant and cancels).
    """
    n_docs = int(idx.doc_lens.size)
    block_size = int(bmax.block_size)
    starts = np.arange(0, n_docs, block_size)
    ratios = []
    for q in queries:
        q = np.asarray(q)
        q = q[(q >= 0) & (q < idx.n_vocab)]
        if q.size == 0:
            continue
        uniq, w = np.unique(q, return_counts=True)
        ub = (bmax.rows(uniq).astype(np.float64)
              * w[:, None]).sum(axis=0)                  # [nb_pad]
        acc = np.zeros(n_docs, dtype=np.float64)
        for t, wt in zip(uniq, w):
            s, e = int(idx.indptr[t]), int(idx.indptr[t + 1])
            np.add.at(acc, idx.doc_ids[s:e], wt * idx.scores[s:e])
        true = np.maximum.reduceat(acc, starts)          # [nb]
        ok = true > 0
        if ok.any():
            ratios.append(ub[:starts.size][ok] / true[ok])
    if not ratios:
        return 1.0
    return float(np.mean(np.concatenate(ratios)))


def bench_pruned_cell(n_docs: int, n_vocab: int, *, profile: str =
                      "head_mixed", batch: int = 2, k: int = 10,
                      block_size: int = 64, avg_len: int = 60,
                      tile: int = 2048, repeats: int = 3) -> dict:
    """One pruned-regime cell: latency + skip rate + transfer audit.

    Measures all four executions on the SAME batch — blocked, gathered
    (serving default), the unpruned resident gather (the pruned regime's
    direct substrate) and pruned — plus the pruning evidence the perf
    gate tracks: ``pruned_skip_rate`` is the fraction of planned
    fragments never DMA'd (pre-launch compaction + in-kernel skips;
    deterministic for fixed seed and code, so a drop means the pruning
    logic regressed, not the runner). ``block_size`` defaults finer than
    the serving default: block-max bounds sharpen as blocks shrink, and
    the resident kernel's fragment grid is what pays for loose ones.
    """
    from repro.serve import DeviceRetriever
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, profile, n_vocab, batch, q_len=5)

    blocked = DeviceRetriever(idx, regime="blocked", tile=tile)
    gathered = DeviceRetriever(idx, regime="gathered", tile=tile)
    resident = DeviceRetriever(idx, regime="gathered", gather="resident",
                               block_size=block_size, frag=512, tile=tile)
    # same postings + grid throughout the cell: later builds adopt the
    # resident CSC arrays / block-max table instead of re-uploading
    # (exercises the rescale reuse path at bench scale, and keeps the
    # CI bench-smoke job's wall time and memory flat)
    pruned = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512,
                             tile=tile, reuse_from=resident.dindex)
    paths = {
        "blocked": lambda: blocked.retrieve_batch(queries, k),
        "gathered": lambda: gathered.retrieve_batch(queries, k),
        "resident": lambda: resident.retrieve_batch(queries, k),
        "pruned": lambda: pruned.retrieve_batch(queries, k),
    }
    for fn in paths.values():
        fn()                                     # compile/warm every path
    times = {name: np.inf for name in paths}
    for _ in range(repeats):
        for name, fn in paths.items():
            gc.collect()
            gc.disable()
            t0 = time.perf_counter()
            fn()
            times[name] = min(times[name], time.perf_counter() - t0)
            gc.enable()
    plan = pruned.last_plan
    dmad = plan.frags_planned - plan.frags_pruned - plan.frags_skipped
    skip_rate = ((plan.frags_planned - dmad) / plan.frags_planned
                 if plan.frags_planned else 0.0)
    best_existing = min(times["blocked"], times["gathered"],
                        times["resident"])

    # steady-state transfer audit for the pruned regime, both planners
    reset_transfer_stats()
    pruned.retrieve_batch(queries, k)
    bytes_post, bytes_desc = (TRANSFERS.posting_bytes,
                              TRANSFERS.descriptor_bytes)
    dev = DeviceRetriever(idx, regime="pruned", plan="device", block_size=block_size,
                          frag=512, tile=tile, reuse_from=pruned.dindex)
    dev.retrieve_batch(queries, k)               # settle buckets
    reset_transfer_stats()
    dev.retrieve_batch(queries, k)
    bytes_post_dev, bytes_desc_dev = (TRANSFERS.posting_bytes,
                                      TRANSFERS.descriptor_bytes)

    # does auto route this batch to the pruned regime?
    auto = DeviceRetriever(idx, regime="auto", gather="resident",
                           block_size=block_size, frag=512, tile=tile,
                           reuse_from=pruned.dindex)
    auto.retrieve_batch(queries, k)

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": profile, "block_size": block_size, "nnz": int(idx.nnz),
        "sum_df": int(plan.sum_df),
        "blocked_batch_s": round(times["blocked"], 4),
        "gathered_batch_s": round(times["gathered"], 4),
        "resident_batch_s": round(times["resident"], 4),
        "pruned_batch_s": round(times["pruned"], 4),
        "pruned_vs_best_existing": round(
            best_existing / max(times["pruned"], 1e-9), 2),
        "frags_planned": int(plan.frags_planned),
        "frags_pruned_prelaunch": int(plan.frags_pruned),
        "frags_skipped_inkernel": int(plan.frags_skipped),
        "frags_dmad": int(dmad),
        "pruned_skip_rate": round(float(skip_rate), 4),
        "bound_tightness": round(
            bound_tightness(idx, pruned.dindex.bmax, queries), 3),
        "survivor_frac_estimate": round(float(plan.survivor_frac or 1.0),
                                        4),
        "auto_picked": auto.last_plan.regime,
        "posting_bytes_per_batch_pruned": int(bytes_post),
        "descriptor_bytes_per_batch_pruned": int(bytes_desc),
        "posting_bytes_per_batch_pruned_device_plan": int(bytes_post_dev),
        "descriptor_bytes_per_batch_pruned_device_plan":
            int(bytes_desc_dev),
    }


def bench_degraded_cell(n_docs: int, n_vocab: int, *, batch: int = 4,
                        k: int = 10, block_size: int = 64,
                        avg_len: int = 60, tile: int = 2048,
                        repeats: int = 3, healthy_batches: int = 20
                        ) -> dict:
    """Degraded-mode column: what each ladder rung costs at one fixed cell.

    Serves the SAME batch from retrievers whose ENTRY rung is each hop of
    ``DeviceRetriever._LADDER`` (pruned / resident / host / blocked), then
    measures one genuinely degraded batch — a deterministic residency
    fault injected into the host gather, so the latency covers the failed
    hop PLUS the fallback (here host → numpy oracle: the worst recovery
    the ladder can take). Results stay exact on every row — degradation
    trades latency, never scores.

    Also reports ``degradations_per_batch_healthy``: the ladder-hop rate
    of a fault-free auto retriever over ``healthy_batches`` batches. The
    perf gate (``benchmarks.perf_gate``) fails on ANY nonzero value — a
    healthy baseline that degrades is a planner/capability bug being
    silently absorbed by the fallback machinery.
    """
    from repro.serve import DeviceRetriever
    from repro.serve.faults import inject_faults

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, "head_mixed", n_vocab, batch, q_len=5)

    resident = DeviceRetriever(idx, regime="gathered", gather="resident",
                               block_size=block_size, frag=512, tile=tile)
    hops = {
        "pruned": DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512,
                                  tile=tile, reuse_from=resident.dindex),
        "resident": resident,
        "host": DeviceRetriever(idx, regime="gathered", gather="host",
                                tile=tile),
        "blocked": DeviceRetriever(idx, regime="blocked", tile=tile,
                                   reuse_from=resident.dindex),
    }
    times = {}
    for name, dr in hops.items():
        dr.retrieve_batch(queries, k)            # compile/warm
        t = np.inf
        for _ in range(repeats):
            gc.collect()
            gc.disable()
            t0 = time.perf_counter()
            dr.retrieve_batch(queries, k)
            t = min(t, time.perf_counter() - t0)
            gc.enable()
        times[name] = t

    # the last rung, measured as a REAL degraded batch: the host gather's
    # upload fails once per batch, the ladder recovers via the oracle
    host = hops["host"]
    spec = {"site": "residency.put_posting_arrays", "kind": "residency",
            "seed": 0}
    t_degraded = np.inf
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        with inject_faults(dict(spec, times=1)):
            t0 = time.perf_counter()
            host.retrieve_batch(queries, k)
            t_degraded = min(t_degraded, time.perf_counter() - t0)
        gc.enable()
    trail = [f"{t['from']}->{t['to']}" for t in host.last_plan.degradations]

    auto = DeviceRetriever(idx, regime="auto", gather="resident",
                           block_size=block_size, frag=512, tile=tile,
                           reuse_from=resident.dindex)
    for _ in range(healthy_batches):
        auto.retrieve_batch(queries, k)
    h = auto.health()
    per_batch = (h["batches_degraded"] / h["batches_served"]
                 if h["batches_served"] else 0.0)

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": "head_mixed", "block_size": block_size,
        "hop_pruned_batch_s": round(times["pruned"], 4),
        "hop_resident_batch_s": round(times["resident"], 4),
        "hop_host_batch_s": round(times["host"], 4),
        "hop_blocked_batch_s": round(times["blocked"], 4),
        "degraded_batch_s": round(t_degraded, 4),
        "degraded_trail": trail,
        "degradations_per_batch_healthy": round(per_batch, 6),
        "healthy_batches_measured": int(h["batches_served"]),
    }


def run(*, fast: bool = False) -> dict:
    from repro.core.retrieval import DEFAULT_CROSSOVER
    if fast:
        grid = [(1_000, 50), (1_000, 2_000), (3_000, 5_000)]
        pruned_grid = [(3_000, 5_000, 2, 10), (3_000, 5_000, 4, 10)]
    else:
        grid = [(2_000, 50), (5_000, 5_000), (20_000, 10_000),
                (50_000, 10_000)]
        pruned_grid = [(20_000, 10_000, 2, 10), (50_000, 10_000, 2, 10),
                       (50_000, 10_000, 4, 10), (50_000, 10_000, 2, 4)]
    cells = [bench_cell(n, v, profile,
                        repeats=4 if n >= 20_000 else 8)
             for n, v in grid
             for profile in (("head", "tail", "dense") if v <= 2_000
                             else ("head", "tail"))]
    pruned_cells = [bench_pruned_cell(n, v, batch=b, k=k,
                                      repeats=3 if n >= 20_000 else 6)
                    for n, v, b, k in pruned_grid]
    # one fixed cell for the ladder's degraded-mode column (PR-6): the
    # biggest sweep point, where the hop-cost spread is widest
    dn, dv = (3_000, 5_000) if fast else (50_000, 10_000)
    degraded_cell = bench_degraded_cell(
        dn, dv, repeats=3 if dn >= 20_000 else 6,
        healthy_batches=10 if fast else 20)

    # implied crossover: the boundary between cells the full scan wins and
    # cells the gather wins, in work-ratio space (planner cells only — the
    # pruned cells appended below carry a different column set)
    blocked_win = [c["work_ratio_nnz_over_sum_df"] for c in cells
                   if c["blocked_batch_s"] < c["gathered_batch_s"]]
    gathered_win = [c["work_ratio_nnz_over_sum_df"] for c in cells
                    if c["gathered_batch_s"] <= c["blocked_batch_s"]]
    if blocked_win and gathered_win:
        suggested = float(np.sqrt(max(blocked_win) * min(gathered_win)))
    elif gathered_win:
        suggested = 1.0                           # gather always won
    else:
        suggested = float(max(blocked_win)) * 2
    pruned_summary = {
        "pruned_beats_best_existing_2x_somewhere": any(
            c["pruned_vs_best_existing"] >= 2.0 for c in pruned_cells),
        "pruned_skip_rates": [c["pruned_skip_rate"] for c in pruned_cells],
        "pruned_bytes_all_zero": all(
            c["posting_bytes_per_batch_pruned"] == 0
            and c["posting_bytes_per_batch_pruned_device_plan"] == 0
            and c["descriptor_bytes_per_batch_pruned_device_plan"] == 0
            for c in pruned_cells),
        "note": "pruned cells: head_mixed queries (1 Zipf-head token + "
                "deep-tail terms), block_size 64 — see bench_pruned_cell. "
                "Exactness is tier-1-asserted (bit-identical to the "
                "single-buffer oracle); these cells measure the work cut.",
    }
    return {
        "cells": cells + pruned_cells,
        "pruned": {"cells": pruned_cells, "summary": pruned_summary},
        "degraded": degraded_cell,
        "summary": {
            # the perf gate fails on ANY nonzero value here: a fault-free
            # baseline run has no business walking the ladder
            "degradations_per_batch_healthy":
                degraded_cell["degradations_per_batch_healthy"],
            "crossover_used": DEFAULT_CROSSOVER,
            "suggested_crossover": round(suggested, 2),
            # auto_batch_s = planned regime's measured latency + measured
            # planning overhead (auto RUNS that exact code path; the raw
            # re-measurement is auto_batch_s_remeasured). The 2ms floor
            # absorbs residual host noise on single-digit-ms cells.
            "auto_within_10pct_of_best_everywhere": all(
                c["auto_vs_best"] <= 1.10 or c["auto_minus_best_s"] <= 0.002
                for c in cells),
            "planner_picked_winner_everywhere": all(
                c["planner_picked_winner"] for c in cells),
            "auto_beats_worst_regime_2x_somewhere": any(
                c["worst_vs_auto"] >= 2.0 for c in cells),
            "resident_posting_bytes_all_zero": all(
                c["posting_bytes_per_batch_resident"] == 0 for c in cells),
            # plan="device": zero posting AND zero descriptor bytes — the
            # fully-device-resident steady state the perf gate enforces
            "device_plan_bytes_all_zero": all(
                c["posting_bytes_per_batch_device_plan"] == 0
                and c["descriptor_bytes_per_batch_device_plan"] == 0
                for c in cells),
            "note": "CPU wall times; Pallas kernels run in interpret mode "
                    "— compare paths relatively. Re-run on TPU and copy "
                    "suggested_crossover into "
                    "core.retrieval.DEFAULT_CROSSOVER to re-calibrate.",
        },
    }


def _guarded_write(path: str, payload: dict, *, fast: bool,
                   force: bool) -> None:
    """Write a bench artifact, refusing to clobber full-scale results.

    Every payload is stamped ``"fast"`` so downstream consumers
    (``benchmarks.perf_gate``) can tell CI-smoke numbers from the real
    sweep. A ``--fast`` run that targets an existing artifact WITHOUT the
    marker aborts unless ``--force`` — the committed full-scale BENCH_*
    files cannot be silently replaced by smoke-sized numbers again (the
    incident behind commit 3b01c1d).
    """
    import os
    payload = {"fast": bool(fast), **payload}
    if fast and not force and os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = None
        if not (isinstance(existing, dict) and existing.get("fast")):
            raise SystemExit(
                f"refusing to overwrite full-scale {path!r} with a --fast "
                f"run; pass --force or point --out elsewhere")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny corpora (CI bench-smoke sized)")
    ap.add_argument("--force", action="store_true",
                    help="allow a --fast run to overwrite a full-scale "
                         "artifact")
    ap.add_argument("--out", default="BENCH_3.json")
    ap.add_argument("--out4", default="BENCH_4.json",
                    help="pruned-regime cells + summary ('' to skip)")
    args = ap.parse_args()
    t0 = time.time()
    result = run(fast=args.fast)
    for c in result["cells"]:
        print("bench3_planner," + ",".join(f"{k}={v}"
                                           for k, v in c.items()),
              flush=True)
    print("bench3_summary," + ",".join(
        f"{k}={v}" for k, v in result["summary"].items()))
    print("bench4_summary," + ",".join(
        f"{k}={v}" for k, v in result["pruned"]["summary"].items()))
    print("bench3_degraded," + ",".join(
        f"{k}={v}" for k, v in result["degraded"].items()))
    _guarded_write(args.out, result, fast=args.fast, force=args.force)
    outs = [args.out]
    if args.out4:
        _guarded_write(args.out4, result["pruned"], fast=args.fast,
                       force=args.force)
        outs.append(args.out4)
    print(f"done in {time.time() - t0:.1f}s -> {', '.join(outs)}")


if __name__ == "__main__":
    main()
